//! Single-decree Paxos per log slot — the sequencing substrate under the
//! replicated coordinator (the paper runs its coordinator as a replicated
//! object inside Replicant, which uses Paxos to order calls into the
//! state-machine library [Lamport 1998]).
//!
//! In-process acceptors keep real ballot/promise/accept state so the
//! protocol's invariants (single value chosen per slot, survival of
//! minority failures, no progress without quorum) hold and are testable,
//! including with failure injection.

use crate::error::{Error, Result};
use std::sync::Mutex;


/// A ballot number: (round, proposer id) with lexicographic order.
#[derive(
    Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord,
)]
pub struct Ballot {
    pub round: u64,
    pub proposer: u32,
}

/// Acceptor state for one log slot.
#[derive(Clone, Debug, Default)]
struct SlotState<C> {
    promised: Ballot,
    accepted: Option<(Ballot, C)>,
}

/// One Paxos acceptor covering a whole log (slot → state).
#[derive(Debug)]
pub struct Acceptor<C> {
    slots: Mutex<Vec<SlotState<C>>>,
    alive: Mutex<bool>,
}

/// One slot's durable image: (promised ballot, accepted value if any).
/// The WAL checkpoints these and restores them on replica restart.
pub type SlotSnapshot<C> = (Ballot, Option<(Ballot, C)>);

/// Phase-1 response.
pub struct Promise<C> {
    pub accepted: Option<(Ballot, C)>,
}

impl<C: Clone> Acceptor<C> {
    pub fn new() -> Self {
        Acceptor {
            slots: Mutex::new(Vec::new()),
            alive: Mutex::new(true),
        }
    }

    pub fn set_alive(&self, alive: bool) {
        *self.alive.lock().unwrap() = alive;
    }

    pub fn is_alive(&self) -> bool {
        *self.alive.lock().unwrap()
    }

    fn with_slot<R>(&self, slot: usize, f: impl FnOnce(&mut SlotState<C>) -> R) -> Option<R>
    where
        C: Default,
    {
        if !self.is_alive() {
            return None;
        }
        let mut g = self.slots.lock().unwrap();
        if g.len() <= slot {
            g.resize_with(slot + 1, SlotState::default);
        }
        Some(f(&mut g[slot]))
    }

    /// Phase 1: promise not to accept ballots below `b`.
    pub fn prepare(&self, slot: usize, b: Ballot) -> Option<Result<Promise<C>>>
    where
        C: Default,
    {
        self.with_slot(slot, |s| {
            if b <= s.promised {
                return Err(Error::TxnConflict {
                    space: crate::types::Space::Sys,
                    key: format!("paxos slot {slot} promised {:?}", s.promised),
                });
            }
            s.promised = b;
            Ok(Promise {
                accepted: s.accepted.clone(),
            })
        })
    }

    /// Phase 2: accept `value` at ballot `b` unless promised higher.
    pub fn accept(&self, slot: usize, b: Ballot, value: C) -> Option<bool>
    where
        C: Default,
    {
        self.with_slot(slot, |s| {
            if b < s.promised {
                return false;
            }
            s.promised = b;
            s.accepted = Some((b, value));
            true
        })
    }

    /// Copy every slot's (promised, accepted) state — the image a WAL
    /// checkpoint persists.  Ignores liveness: checkpointing happens
    /// under the owning replica's own aliveness guard.
    pub fn snapshot_slots(&self) -> Vec<SlotSnapshot<C>> {
        self.slots
            .lock()
            .unwrap()
            .iter()
            .map(|s| (s.promised, s.accepted.clone()))
            .collect()
    }

    /// Replace the whole slot table with a recovered image (restart
    /// from WAL replay).  Anything not in `slots` never survived the
    /// crash and must not be resurrected.
    pub fn restore_slots(&self, slots: Vec<SlotSnapshot<C>>) {
        *self.slots.lock().unwrap() = slots
            .into_iter()
            .map(|(promised, accepted)| SlotState { promised, accepted })
            .collect();
    }

    /// Forget all promises and accepts: a real (durable-mode) crash —
    /// whatever the WAL cannot re-derive is gone.
    pub fn wipe(&self) {
        self.slots.lock().unwrap().clear();
    }
}

/// Drive one slot to a decision across `acceptors`.  Returns the chosen
/// command — which may be a previously-accepted one that must be adopted.
pub fn propose<C: Clone + Default>(
    acceptors: &[Acceptor<C>],
    slot: usize,
    proposer: u32,
    value: C,
) -> Result<C> {
    let total = acceptors.len();
    let quorum = total / 2 + 1;
    let mut round = 1u64;
    for _attempt in 0..16 {
        let ballot = Ballot { round, proposer };
        // Phase 1.
        let mut promises = Vec::new();
        let mut alive = 0;
        for a in acceptors {
            match a.prepare(slot, ballot) {
                None => continue, // dead
                Some(Err(_)) => {
                    alive += 1;
                    continue; // promised higher; retry with bigger round
                }
                Some(Ok(p)) => {
                    alive += 1;
                    promises.push(p);
                }
            }
        }
        if alive < quorum {
            return Err(Error::NoQuorum { alive, total });
        }
        if promises.len() < quorum {
            round += 2;
            continue;
        }
        // Adopt the highest previously-accepted value, if any.
        let chosen = promises
            .iter()
            .filter_map(|p| p.accepted.clone())
            .max_by_key(|(b, _)| *b)
            .map(|(_, v)| v)
            .unwrap_or_else(|| value.clone());
        // Phase 2.
        let acks = acceptors
            .iter()
            .filter_map(|a| a.accept(slot, ballot, chosen.clone()))
            .filter(|ok| *ok)
            .count();
        if acks >= quorum {
            return Ok(chosen);
        }
        round += 2;
    }
    Err(Error::NoQuorum {
        alive: 0,
        total,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn acceptors(n: usize) -> Vec<Acceptor<u64>> {
        (0..n).map(|_| Acceptor::new()).collect()
    }

    #[test]
    fn single_proposer_decides_its_value() {
        let a = acceptors(3);
        assert_eq!(propose(&a, 0, 1, 42).unwrap(), 42);
    }

    #[test]
    fn second_proposer_adopts_chosen_value() {
        let a = acceptors(3);
        assert_eq!(propose(&a, 0, 1, 42).unwrap(), 42);
        // A different proposer with a different value must learn 42.
        assert_eq!(propose(&a, 0, 2, 99).unwrap(), 42);
    }

    #[test]
    fn distinct_slots_are_independent() {
        let a = acceptors(3);
        assert_eq!(propose(&a, 0, 1, 1).unwrap(), 1);
        assert_eq!(propose(&a, 1, 1, 2).unwrap(), 2);
    }

    #[test]
    fn survives_minority_failure() {
        let a = acceptors(3);
        a[2].set_alive(false);
        assert_eq!(propose(&a, 0, 1, 7).unwrap(), 7);
    }

    #[test]
    fn no_progress_without_quorum() {
        let a = acceptors(3);
        a[1].set_alive(false);
        a[2].set_alive(false);
        assert!(matches!(
            propose(&a, 0, 1, 7),
            Err(Error::NoQuorum { alive: 1, total: 3 })
        ));
    }

    #[test]
    fn value_chosen_with_minority_then_visible_after_recovery() {
        let a = acceptors(3);
        a[0].set_alive(false);
        assert_eq!(propose(&a, 0, 1, 5).unwrap(), 5);
        a[0].set_alive(true);
        a[2].set_alive(false); // different minority fails
        assert_eq!(propose(&a, 0, 2, 9).unwrap(), 5, "chosen value is stable");
    }
}
