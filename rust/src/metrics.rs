//! Lightweight counters threaded through every layer.
//!
//! The evaluation's Table 2 is literally these counters: bytes read and
//! written by the *storage* layer per application phase.  Counters are
//! lock-free and cheap enough to leave enabled on the hot path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Byte/op counters for one component (a storage server, a client, a
/// benchmark phase).  Cloning shares the underlying counters.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    inner: Arc<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    bytes_read: AtomicU64,
    bytes_written: AtomicU64,
    ops_read: AtomicU64,
    ops_written: AtomicU64,
    meta_txns: AtomicU64,
    meta_conflicts: AtomicU64,
    txn_retries: AtomicU64,
    gc_bytes_reclaimed: AtomicU64,
    gc_bytes_rewritten: AtomicU64,
}

macro_rules! counter {
    ($add:ident, $get:ident, $field:ident) => {
        #[inline]
        pub fn $add(&self, n: u64) {
            self.inner.$field.fetch_add(n, Ordering::Relaxed);
        }
        #[inline]
        pub fn $get(&self) -> u64 {
            self.inner.$field.load(Ordering::Relaxed)
        }
    };
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    counter!(add_bytes_read, bytes_read, bytes_read);
    counter!(add_bytes_written, bytes_written, bytes_written);
    counter!(add_ops_read, ops_read, ops_read);
    counter!(add_ops_written, ops_written, ops_written);
    counter!(add_meta_txns, meta_txns, meta_txns);
    counter!(add_meta_conflicts, meta_conflicts, meta_conflicts);
    counter!(add_txn_retries, txn_retries, txn_retries);
    counter!(add_gc_reclaimed, gc_bytes_reclaimed, gc_bytes_reclaimed);
    counter!(add_gc_rewritten, gc_bytes_rewritten, gc_bytes_rewritten);

    /// Snapshot for delta accounting across a benchmark phase.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            bytes_read: self.bytes_read(),
            bytes_written: self.bytes_written(),
            ops_read: self.ops_read(),
            ops_written: self.ops_written(),
            meta_txns: self.meta_txns(),
            meta_conflicts: self.meta_conflicts(),
            txn_retries: self.txn_retries(),
            gc_bytes_reclaimed: self.gc_bytes_reclaimed(),
            gc_bytes_rewritten: self.gc_bytes_rewritten(),
        }
    }
}

/// Point-in-time copy of the counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub ops_read: u64,
    pub ops_written: u64,
    pub meta_txns: u64,
    pub meta_conflicts: u64,
    pub txn_retries: u64,
    pub gc_bytes_reclaimed: u64,
    pub gc_bytes_rewritten: u64,
}

impl MetricsSnapshot {
    /// Per-field difference `self - earlier` (saturating).
    pub fn delta(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            bytes_read: self.bytes_read.saturating_sub(earlier.bytes_read),
            bytes_written: self.bytes_written.saturating_sub(earlier.bytes_written),
            ops_read: self.ops_read.saturating_sub(earlier.ops_read),
            ops_written: self.ops_written.saturating_sub(earlier.ops_written),
            meta_txns: self.meta_txns.saturating_sub(earlier.meta_txns),
            meta_conflicts: self.meta_conflicts.saturating_sub(earlier.meta_conflicts),
            txn_retries: self.txn_retries.saturating_sub(earlier.txn_retries),
            gc_bytes_reclaimed: self
                .gc_bytes_reclaimed
                .saturating_sub(earlier.gc_bytes_reclaimed),
            gc_bytes_rewritten: self
                .gc_bytes_rewritten
                .saturating_sub(earlier.gc_bytes_rewritten),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_share() {
        let m = Metrics::new();
        let m2 = m.clone();
        m.add_bytes_read(10);
        m2.add_bytes_read(5);
        assert_eq!(m.bytes_read(), 15);
        m.add_meta_txns(1);
        assert_eq!(m2.meta_txns(), 1);
    }

    #[test]
    fn snapshot_delta() {
        let m = Metrics::new();
        m.add_bytes_written(100);
        let a = m.snapshot();
        m.add_bytes_written(50);
        m.add_txn_retries(2);
        let d = m.snapshot().delta(&a);
        assert_eq!(d.bytes_written, 50);
        assert_eq!(d.txn_retries, 2);
        assert_eq!(d.bytes_read, 0);
    }
}
