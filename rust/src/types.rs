//! Core datatypes shared by every layer: slice pointers, region metadata,
//! inodes, and the metadata-store key space.
//!
//! The paper's central representation (§2.1): a file is a sequence of
//! *slices* — immutable, byte-addressable, arbitrarily sized byte arrays —
//! plus the offsets at which they are overlaid.  Everything needed to fetch
//! a slice lives inside its [`SlicePtr`]; the metadata store holds only
//! lists of these pointers.


use std::collections::BTreeMap;
use std::fmt;

/// Identifier of a storage server (assigned by the coordinator).
pub type ServerId = u32;
/// Identifier of an inode.
pub type InodeId = u64;
/// Identifier of a backing file within one storage server.
pub type BackingId = u32;

/// A pointer to an immutable slice of bytes on a storage server (§2.1).
///
/// The tuple `(server, backing file, offset, length)` is self-contained:
/// no other bookkeeping anywhere in the system is needed to retrieve the
/// bytes.  Sub-slicing is pure arithmetic ([`SlicePtr::slice`]), which is
/// what makes yank/paste metadata-only operations.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct SlicePtr {
    /// Storage server holding the slice.
    pub server: ServerId,
    /// Backing file on that server.
    pub backing: BackingId,
    /// Byte offset of the slice within the backing file.
    pub offset: u64,
    /// Length of the slice in bytes.
    pub len: u64,
}

impl fmt::Debug for SlicePtr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "s{}:b{}@{}+{}",
            self.server, self.backing, self.offset, self.len
        )
    }
}

impl SlicePtr {
    /// Sub-slice `[from, to)` (relative to this slice) by pure arithmetic.
    ///
    /// Panics if `from > to || to > len` — callers validate ranges at the
    /// API boundary.
    pub fn slice(&self, from: u64, to: u64) -> SlicePtr {
        assert!(from <= to && to <= self.len, "sub-slice out of range");
        SlicePtr {
            server: self.server,
            backing: self.backing,
            offset: self.offset + from,
            len: to - from,
        }
    }

    /// True when `other` begins exactly where `self` ends in the same
    /// backing file — the locality-aware-placement property (§2.7) that
    /// lets compaction fuse adjacent slices into one pointer.
    pub fn is_adjacent(&self, other: &SlicePtr) -> bool {
        self.server == other.server
            && self.backing == other.backing
            && self.offset + self.len == other.offset
    }

    /// Fuse `other` onto the end of `self` (requires [`Self::is_adjacent`]).
    pub fn fuse(&self, other: &SlicePtr) -> SlicePtr {
        debug_assert!(self.is_adjacent(other));
        SlicePtr {
            len: self.len + other.len,
            ..*self
        }
    }
}

/// The payload of a region-metadata entry: replicated stored bytes, or a
/// hole created by `punch` (reads as zeros, occupies no storage).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SliceData {
    /// One pointer per replica; all replicas hold identical bytes and a
    /// reader may use any of them (§2.9).
    Stored(Vec<SlicePtr>),
    /// An explicit zero-range (from `punch`), freeing underlying storage.
    Hole,
}

impl SliceData {
    /// Primary replica pointer, if stored.
    pub fn primary(&self) -> Option<&SlicePtr> {
        match self {
            SliceData::Stored(v) => v.first(),
            SliceData::Hole => None,
        }
    }

    /// Length in bytes represented by this payload (replicas are equal).
    pub fn len(&self) -> Option<u64> {
        self.primary().map(|p| p.len)
    }

    pub fn is_hole(&self) -> bool {
        matches!(self, SliceData::Hole)
    }

    /// Arithmetic sub-slice of every replica (holes stay holes).
    pub fn slice(&self, from: u64, to: u64) -> SliceData {
        match self {
            SliceData::Stored(v) => {
                SliceData::Stored(v.iter().map(|p| p.slice(from, to)).collect())
            }
            SliceData::Hole => SliceData::Hole,
        }
    }
}

/// Where a region entry is overlaid (§2.1, §2.5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// At an explicit region-relative byte offset.
    At(u64),
    /// Relative to the end of the region at apply time — the conditional
    /// append fast path that lets concurrent appends commute.
    Eof,
}

/// One entry in a region's metadata list: a placement, a length, and the
/// slice payload.  Later entries take precedence over earlier ones where
/// they overlap (Fig. 2).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RegionEntry {
    pub placement: Placement,
    pub len: u64,
    pub data: SliceData,
}

/// The metadata object for one fixed-size region of a file (§2.3), stored
/// under its own deterministically derived key in the metadata store.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RegionMeta {
    /// Tier-2 garbage collection (§2.8): when a compacted list is still
    /// too fragmented, its entries are serialized into a slice on the
    /// storage servers and this replicated pointer replaces them.  The
    /// spilled entries form the *base* overlay; `entries` apply on top.
    pub spill: Option<Vec<SlicePtr>>,
    /// Overlay list, in write order.
    pub entries: Vec<RegionEntry>,
    /// Region-relative end of written data — maintained so EOF-relative
    /// appends can be validated without reading the whole list.
    pub eof: u64,
}

impl RegionMeta {
    /// Number of entries (proxy for metadata size / fragmentation).
    pub fn fragmentation(&self) -> usize {
        self.entries.len()
    }
}

/// A region of a file: `(inode, index)`; region `i` covers file bytes
/// `[i * region_size, (i+1) * region_size)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RegionId {
    pub inode: InodeId,
    pub index: u32,
}

impl RegionId {
    pub fn new(inode: InodeId, index: u32) -> Self {
        RegionId { inode, index }
    }

    /// Deterministic metadata-store key (§2.3).
    pub fn key(&self) -> String {
        format!("{:016x}#{:08x}", self.inode, self.index)
    }
}

/// Inode contents (§2.4): standard POSIX-ish info, plus the
/// highest-written region so clients can find the end of file in one hop.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Inode {
    pub id: InodeId,
    pub kind: InodeKind,
    /// Hard-link count.
    pub links: u32,
    /// File length in bytes (monotone max under concurrent writers).
    pub len: u64,
    /// Modification time (seconds since epoch; virtual in sim mode).
    pub mtime: u64,
    /// Permissions bits (checked on the inode, not the full path — §2.4).
    pub mode: u32,
    pub owner: u32,
    pub group: u32,
    /// Highest region index ever written (EOF discovery hint).
    pub highest_region: u32,
    /// Replication factor for this file's slices.
    pub replication: u8,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InodeKind {
    File,
    Directory,
}

impl Inode {
    pub fn new_file(id: InodeId, mode: u32, replication: u8) -> Self {
        Inode {
            id,
            kind: InodeKind::File,
            links: 1,
            len: 0,
            mtime: 0,
            mode,
            owner: 0,
            group: 0,
            highest_region: 0,
            replication,
        }
    }

    pub fn new_directory(id: InodeId, mode: u32) -> Self {
        Inode {
            id,
            kind: InodeKind::Directory,
            links: 1,
            len: 0,
            mtime: 0,
            mode,
            owner: 0,
            group: 0,
            highest_region: 0,
            replication: 1,
        }
    }

    pub fn is_dir(&self) -> bool {
        self.kind == InodeKind::Directory
    }
}

/// Directory contents: name → inode.  The paper stores directories as
/// special files alongside the one-lookup path map (§2.4); we keep them as
/// a first-class value in the metadata store, updated in the same
/// transactions — the same atomicity with less indirection (DESIGN.md §5).
pub type DirEntries = BTreeMap<String, InodeId>;

/// Metadata-store value. One variant per schema ("space" in HyperDex
/// terms); transactions span spaces freely (§2.4).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Value {
    /// `Space::Path`: pathname → inode id (one-lookup open).
    PathEntry(InodeId),
    /// `Space::Inode`: the inode.
    Inode(Inode),
    /// `Space::Region`: one region's overlay list.
    Region(RegionMeta),
    /// `Space::Dir`: directory entries.
    Dir(DirEntries),
    /// `Space::Sys`: counters (e.g. the inode-id allocator) and GC state.
    U64(u64),
    /// GC scan output and other blobs.
    Bytes(Vec<u8>),
}

impl Value {
    pub fn as_region(&self) -> Option<&RegionMeta> {
        match self {
            Value::Region(r) => Some(r),
            _ => None,
        }
    }
    pub fn as_inode(&self) -> Option<&Inode> {
        match self {
            Value::Inode(i) => Some(i),
            _ => None,
        }
    }
    pub fn as_dir(&self) -> Option<&DirEntries> {
        match self {
            Value::Dir(d) => Some(d),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::U64(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_path_entry(&self) -> Option<InodeId> {
        match self {
            Value::PathEntry(i) => Some(*i),
            _ => None,
        }
    }
}

/// The metadata store's independent schemas.  HyperDex transactions span
/// multiple keys across independent schemas (§2.4); so do ours.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Space {
    /// Absolute pathname → inode id.
    Path,
    /// Inode id → inode.
    Inode,
    /// Region key → region metadata list.
    Region,
    /// Directory inode id → entries.
    Dir,
    /// System counters, GC scan blobs.
    Sys,
}

/// A fully-qualified metadata key.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Key {
    pub space: Space,
    pub key: String,
}

impl Key {
    pub fn new(space: Space, key: impl Into<String>) -> Self {
        Key {
            space,
            key: key.into(),
        }
    }
    pub fn path(p: impl Into<String>) -> Self {
        Key::new(Space::Path, p)
    }
    pub fn inode(id: InodeId) -> Self {
        Key::new(Space::Inode, format!("{id:016x}"))
    }
    pub fn region(r: RegionId) -> Self {
        Key::new(Space::Region, r.key())
    }
    pub fn dir(id: InodeId) -> Self {
        Key::new(Space::Dir, format!("{id:016x}"))
    }
    pub fn sys(name: impl Into<String>) -> Self {
        Key::new(Space::Sys, name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ptr(server: ServerId, backing: BackingId, offset: u64, len: u64) -> SlicePtr {
        SlicePtr {
            server,
            backing,
            offset,
            len,
        }
    }

    #[test]
    fn sub_slice_arithmetic() {
        let p = ptr(1, 2, 100, 50);
        let s = p.slice(10, 30);
        assert_eq!(s, ptr(1, 2, 110, 20));
        assert_eq!(p.slice(0, 50), p);
        assert_eq!(p.slice(50, 50).len, 0);
    }

    #[test]
    #[should_panic]
    fn sub_slice_out_of_range_panics() {
        ptr(1, 2, 100, 50).slice(10, 51);
    }

    #[test]
    fn adjacency_and_fuse() {
        let a = ptr(1, 1, 0, 10);
        let b = ptr(1, 1, 10, 5);
        let c = ptr(1, 2, 10, 5);
        let d = ptr(2, 1, 10, 5);
        assert!(a.is_adjacent(&b));
        assert!(!a.is_adjacent(&c));
        assert!(!a.is_adjacent(&d));
        assert!(!b.is_adjacent(&a));
        assert_eq!(a.fuse(&b), ptr(1, 1, 0, 15));
    }

    #[test]
    fn slice_data_ops() {
        let s = SliceData::Stored(vec![ptr(1, 1, 0, 10), ptr(2, 3, 40, 10)]);
        assert_eq!(s.len(), Some(10));
        let sub = s.slice(2, 6);
        match sub {
            SliceData::Stored(v) => {
                assert_eq!(v, vec![ptr(1, 1, 2, 4), ptr(2, 3, 42, 4)]);
            }
            _ => panic!(),
        }
        assert!(SliceData::Hole.is_hole());
        assert_eq!(SliceData::Hole.len(), None);
        assert_eq!(SliceData::Hole.slice(1, 2), SliceData::Hole);
    }

    #[test]
    fn region_key_is_deterministic_and_distinct() {
        let a = RegionId::new(7, 0).key();
        let b = RegionId::new(7, 1).key();
        let c = RegionId::new(8, 0).key();
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, RegionId::new(7, 0).key());
    }

    #[test]
    fn inode_constructors() {
        let f = Inode::new_file(1, 0o644, 2);
        assert!(!f.is_dir());
        assert_eq!(f.links, 1);
        assert_eq!(f.replication, 2);
        let d = Inode::new_directory(2, 0o755);
        assert!(d.is_dir());
    }

    #[test]
    fn value_accessors() {
        assert_eq!(Value::U64(9).as_u64(), Some(9));
        assert_eq!(Value::PathEntry(3).as_path_entry(), Some(3));
        assert!(Value::U64(9).as_inode().is_none());
        let r = Value::Region(RegionMeta::default());
        assert!(r.as_region().is_some());
    }
}
