//! A small measurement harness (stand-in for criterion in the offline
//! build): warmup, timed iterations, summary statistics, throughput.

use super::stats::{fmt_ns, fmt_rate, Summary};
use std::time::Instant;

/// One registered benchmark run.
pub struct Bench {
    name: String,
    warmup: u32,
    iters: u32,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Bench {
        Bench {
            name: name.into(),
            warmup: 3,
            iters: 20,
        }
    }

    pub fn warmup(mut self, n: u32) -> Self {
        self.warmup = n;
        self
    }

    pub fn iters(mut self, n: u32) -> Self {
        self.iters = n;
        self
    }

    /// Run `f` (whose return value is black-boxed) and print a summary.
    /// Returns the per-iteration summary for programmatic use.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Summary {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters as usize);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_nanos() as u64);
        }
        let s = Summary::of(&samples);
        println!(
            "{:<44} {:>12}/iter  p50 {:>10}  p95 {:>10}  (n={})",
            self.name,
            fmt_ns(s.mean as u64),
            fmt_ns(s.p50),
            fmt_ns(s.p95),
            s.n
        );
        s
    }

    /// Like [`Bench::run`] but also reports throughput for `bytes`
    /// processed per iteration.
    pub fn run_bytes<T>(&self, bytes: u64, f: impl FnMut() -> T) -> Summary {
        let s = self.run(f);
        if s.mean > 0.0 {
            let rate = bytes as f64 / (s.mean / 1e9);
            println!("{:<44} {:>14}", format!("  └─ throughput ({bytes} B)"), fmt_rate(rate));
        }
        s
    }
}

/// Opaque value sink that defeats dead-code elimination without unsafe
/// (std::hint::black_box is stable).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = Bench::new("noop").warmup(1).iters(5).run(|| 1 + 1);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn bench_bytes_reports() {
        let s = Bench::new("memcpy")
            .warmup(1)
            .iters(5)
            .run_bytes(1 << 20, || vec![0u8; 1 << 20]);
        assert!(s.mean > 0.0);
    }
}
