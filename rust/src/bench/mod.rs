//! Benchmark infrastructure: statistics, a small bench harness (the
//! offline build has no criterion), and the experiment suite that
//! regenerates every table and figure of the paper's evaluation.
//!
//! Entry point: `repro bench --exp <id>` (see `rust/src/main.rs`), or
//! programmatically via [`exps`].

pub mod exps;
pub mod harness;
pub mod stats;

pub use harness::Bench;
pub use stats::Summary;
