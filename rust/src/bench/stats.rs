//! Percentiles, means, and standard errors for benchmark reporting —
//! the quantities in the paper's error bars (stderr of the mean across
//! trials; p5/p95 and p99 latencies).

/// Summary statistics over a sample of u64 measurements (ns or bytes).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stderr: f64,
    pub min: u64,
    pub max: u64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub p5: u64,
}

impl Summary {
    /// Compute from an unsorted sample.  Empty input yields zeros.
    pub fn of(sample: &[u64]) -> Summary {
        if sample.is_empty() {
            return Summary::default();
        }
        let mut v = sample.to_vec();
        v.sort_unstable();
        let n = v.len();
        let mean = v.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var = v
            .iter()
            .map(|&x| {
                let d = x as f64 - mean;
                d * d
            })
            .sum::<f64>()
            / n.max(2).saturating_sub(1) as f64;
        Summary {
            n,
            mean,
            stderr: (var / n as f64).sqrt(),
            min: v[0],
            max: v[n - 1],
            p50: pct(&v, 50.0),
            p95: pct(&v, 95.0),
            p99: pct(&v, 99.0),
            p5: pct(&v, 5.0),
        }
    }
}

/// Nearest-rank percentile of a sorted sample.
pub fn pct(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: u64) -> String {
    match ns {
        0..=9_999 => format!("{ns} ns"),
        10_000..=9_999_999 => format!("{:.1} µs", ns as f64 / 1e3),
        10_000_000..=1_999_999_999 => format!("{:.1} ms", ns as f64 / 1e6),
        _ => format!("{:.2} s", ns as f64 / 1e9),
    }
}

/// Format bytes/second human-readably.
pub fn fmt_rate(bytes_per_sec: f64) -> String {
    if bytes_per_sec >= 1e9 {
        format!("{:.2} GB/s", bytes_per_sec / 1e9)
    } else if bytes_per_sec >= 1e6 {
        format!("{:.1} MB/s", bytes_per_sec / 1e6)
    } else {
        format!("{:.1} kB/s", bytes_per_sec / 1e3)
    }
}

/// Format a byte count.
pub fn fmt_bytes(b: u64) -> String {
    if b >= 1 << 30 {
        format!("{:.2} GB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.1} MB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1} kB", b as f64 / 1024.0)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_uniform() {
        let v: Vec<u64> = (1..=100).collect();
        let s = Summary::of(&v);
        assert_eq!(s.n, 100);
        assert_eq!(s.mean, 50.5);
        assert_eq!(s.p50, 50);
        assert_eq!(s.p95, 95);
        assert_eq!(s.p99, 99);
        assert_eq!(s.p5, 5);
        assert_eq!((s.min, s.max), (1, 100));
        assert!(s.stderr > 2.8 && s.stderr < 3.0, "{}", s.stderr);
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(Summary::of(&[]).n, 0);
        let s = Summary::of(&[7]);
        assert_eq!(s.p50, 7);
        assert_eq!(s.mean, 7.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(12), "12 ns");
        assert_eq!(fmt_ns(15_000), "15.0 µs");
        assert_eq!(fmt_ns(15_000_000), "15.0 ms");
        assert_eq!(fmt_ns(3_000_000_000), "3.00 s");
        assert_eq!(fmt_rate(400e6), "400.0 MB/s");
        assert_eq!(fmt_rate(9.3e9), "9.30 GB/s");
        assert_eq!(fmt_bytes(100 << 20), "100.0 MB");
    }
}
