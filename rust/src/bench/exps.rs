//! The experiment suite: one entry per table/figure of the paper's
//! evaluation (§4), reachable via `repro bench --exp <id>`.
//!
//! Micro-benchmarks (Figs. 6–15) run on the calibrated discrete-event
//! simulator (DESIGN.md §5); Table 2 and the small-scale sort also run
//! for real on the in-process cluster with measured I/O counters.  Each
//! experiment returns structured [`Row`]s so tests can assert the
//! paper's shapes, and prints them as the same series the paper plots.

use crate::bench::stats::{fmt_bytes, Summary};
use crate::cluster::Cluster;
use crate::config::Config;
use crate::error::{Error, Result};
use crate::mapreduce::records::generate_records;
use crate::mapreduce::{
    sort_conventional_probed, sort_slicing_probed, SortJob, SortStats,
};
use crate::runtime::NativeCompute;
use crate::sim::engine::{run_closed_loop, run_pipelined};
use crate::sim::model::{ClusterModel, OpKind};
use crate::sim::Testbed;

const MB: u64 = 1 << 20;
const GB: u64 = 1 << 30;
/// Decimal gigabyte, for scaling to the paper's "100 GB" figures.
const DEC_GB: f64 = 1e9;

/// One data point of a figure/table.
#[derive(Clone, Debug)]
pub struct Row {
    pub series: String,
    pub x: String,
    pub value: f64,
    pub unit: &'static str,
}

impl Row {
    fn new(series: impl Into<String>, x: impl Into<String>, value: f64, unit: &'static str) -> Row {
        Row {
            series: series.into(),
            x: x.into(),
            value,
            unit,
        }
    }
}

/// A completed experiment.
#[derive(Clone, Debug)]
pub struct ExpReport {
    pub id: &'static str,
    pub title: &'static str,
    pub rows: Vec<Row>,
    pub commentary: Vec<String>,
}

impl ExpReport {
    pub fn print(&self) {
        println!("== {} — {} ==", self.id, self.title);
        for r in &self.rows {
            println!("  {:<28} {:<14} {:>14.3} {}", r.series, r.x, r.value, r.unit);
        }
        for c in &self.commentary {
            println!("  # {c}");
        }
        println!();
    }

    /// Value of the first row matching `(series, x)`.
    pub fn value(&self, series: &str, x: &str) -> Option<f64> {
        self.rows
            .iter()
            .find(|r| r.series == series && r.x == x)
            .map(|r| r.value)
    }

    /// All values of a series, in row order.
    pub fn series(&self, series: &str) -> Vec<f64> {
        self.rows
            .iter()
            .filter(|r| r.series == series)
            .map(|r| r.value)
            .collect()
    }
}

/// Run one experiment by id.  `quick` shrinks workloads for CI.
pub fn run(exp: &str, quick: bool) -> Result<ExpReport> {
    match exp {
        "table2" => table2(quick),
        "fig4" => fig4_5(quick).map(|(a, _)| a),
        "fig5" => fig4_5(quick).map(|(_, b)| b),
        "fig6" => fig6(),
        "fig7" => fig7_8(quick).map(|(a, _)| a),
        "fig8" => fig7_8(quick).map(|(_, b)| b),
        "fig9" => fig9_10(quick).map(|(a, _)| a),
        "fig10" => fig9_10(quick).map(|(_, b)| b),
        "fig11" => fig11(quick),
        "fig12" => fig12(quick),
        "fig13" => fig13_14(quick).map(|(a, _)| a),
        "fig14" => fig13_14(quick).map(|(_, b)| b),
        "fig15" => fig15(quick),
        other => Err(Error::InvalidArgument(format!("unknown experiment {other}"))),
    }
}

/// Every experiment id, in paper order.
pub fn all_experiments() -> &'static [&'static str] {
    &[
        "table2", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11",
        "fig12", "fig13", "fig14", "fig15",
    ]
}

// ====================================================================
// Table 2 — sort I/O per stage, measured on the real in-process cluster
// ====================================================================

fn table2(quick: bool) -> Result<ExpReport> {
    let records: u64 = if quick { 512 } else { 4096 };
    let mut job = SortJob::new(64, 8);
    job.chunk_records = 128;
    let data = generate_records(records, job.fmt, 2015);
    let input_size = data.len() as u64;

    let run = |slicing: bool| -> Result<(SortStats, u64)> {
        let cluster = Cluster::builder().config(Config::test()).build()?;
        let c = cluster.client();
        crate::mapreduce::BulkFs::write_file(&c, "/input", &data)?;
        let probe = {
            let cl = &cluster;
            move || (cl.storage_bytes_read(), cl.storage_bytes_written())
        };
        let stats = if slicing {
            sort_slicing_probed(&c, &NativeCompute, "/input", "/out", &job, Some(&probe))?
        } else {
            sort_conventional_probed(&c, &NativeCompute, "/input", "/out", &job, Some(&probe))?
        };
        Ok((stats, input_size))
    };

    let (conv, _) = run(false)?;
    let (slice, _) = run(true)?;

    // Scale measured bytes to the paper's 100 GB input.
    let scale = 100.0 * DEC_GB / input_size as f64;
    let gb = |b: u64| (b as f64 * scale) / DEC_GB;

    let mut rows = Vec::new();
    for (stage, c_io, s_io) in [
        ("bucketing", conv.bucketing_io, slice.bucketing_io),
        ("sorting", conv.sorting_io, slice.sorting_io),
        ("merging", conv.merging_io, slice.merging_io),
    ] {
        rows.push(Row::new("conventional-R", stage, gb(c_io.0), "GB"));
        rows.push(Row::new("conventional-W", stage, gb(c_io.1), "GB"));
        rows.push(Row::new("slicing-R", stage, gb(s_io.0), "GB"));
        rows.push(Row::new("slicing-W", stage, gb(s_io.1), "GB"));
    }
    let conv_r = gb(conv.bucketing_io.0 + conv.sorting_io.0 + conv.merging_io.0);
    let conv_w = gb(conv.bucketing_io.1 + conv.sorting_io.1 + conv.merging_io.1);
    let slice_r = gb(slice.bucketing_io.0 + slice.sorting_io.0 + slice.merging_io.0);
    let slice_w = gb(slice.bucketing_io.1 + slice.sorting_io.1 + slice.merging_io.1);
    rows.push(Row::new("conventional-R", "total", conv_r, "GB"));
    rows.push(Row::new("conventional-W", "total", conv_w, "GB"));
    rows.push(Row::new("slicing-R", "total", slice_r, "GB"));
    rows.push(Row::new("slicing-W", "total", slice_w, "GB"));

    Ok(ExpReport {
        id: "table2",
        title: "sort I/O per stage, scaled to a 100 GB input (paper: 300R/300W vs 200R/0W)",
        rows,
        commentary: vec![
            format!(
                "measured on a real in-process cluster sorting {} of records ({} x {} B)",
                fmt_bytes(input_size),
                records,
                64
            ),
            format!(
                "conventional {conv_r:.0} GB read / {conv_w:.0} GB written; slicing {slice_r:.0} GB read / {slice_w:.0} GB written"
            ),
            "paper Table 2: conventional 300R/300W, slicing 200R/0W (writes here include 2x replication of the final output in conventional mode)".into(),
        ],
    })
}

// ====================================================================
// Figures 4 & 5 — sort wall-clock, simulated at paper scale
// ====================================================================

/// Simulate the three-stage sort at paper scale on the DES model.
/// Twelve pipelined workers stream `total` bytes in 4 MB operations.
fn sort_sim(total: u64, slicing: bool, hdfs: bool) -> (f64, f64, f64) {
    let tb = Testbed::default();
    let clients = 12usize;
    let chunk = 4 * MB;
    // CPU cost of the in-memory sort itself (~40 ns/B on 2008 Xeons);
    // both systems pay it during the sorting stage.
    let cpu_ns_per_byte = 40u64;
    let ops_per_stage = (total / chunk / clients as u64).max(1) as usize;

    let mut model = ClusterModel::new(tb, clients, 9);
    // One stage: read a chunk, optionally CPU-process, then write it
    // back (conventional) or commit a metadata paste (slicing).
    let stage = |model: &mut ClusterModel, start_at: u64, cpu: u64, write_back: bool| -> u64 {
        model.reset_streams();
        let (_, end) = run_pipelined(clients, ops_per_stage, |c, _, now| {
            let now = now.max(start_at);
            let (r_adv, r_done) = if hdfs {
                model.hdfs_seq_read_op(c, chunk, now)
            } else {
                model.wtf_read_op(c, chunk, OpKind::SeqRead, now)
            };
            let processed = r_done + cpu;
            let (w_adv, w_done) = if !write_back {
                // Slicing: one metadata transaction, zero data bytes.
                model.wtf_write_op(c, 0, OpKind::SeqWrite, processed)
            } else if hdfs {
                model.hdfs_write_op(c, chunk, processed)
            } else {
                model.wtf_write_op(c, chunk, OpKind::SeqWrite, processed)
            };
            (r_adv.max(w_adv.min(w_done)), w_done)
        });
        end
    };

    let cpu_per_chunk = cpu_ns_per_byte * chunk;
    // Stage 1: bucketing (no CPU beyond classification, which the AOT
    // kernel does at memory speed).
    let t_bucket = stage(&mut model, 0, 0, !slicing);
    // Stage 2: per-bucket sort.
    let t_sort = stage(&mut model, t_bucket, cpu_per_chunk, !slicing);
    // Stage 3: merge — concat (metadata only) or a full copy pass.
    let t_merge = if slicing {
        // One concat transaction per bucket: a handful of metadata RTTs.
        t_sort + 16 * 4_000_000
    } else {
        stage(&mut model, t_sort, 0, true)
    };
    (
        t_bucket as f64 / 1e9,
        (t_sort - t_bucket) as f64 / 1e9,
        (t_merge - t_sort) as f64 / 1e9,
    )
}

fn fig4_5(quick: bool) -> Result<(ExpReport, ExpReport)> {
    let total = if quick { 2 * GB } else { 100 * GB };
    let (hb, hs, hm) = sort_sim(total, false, true);
    let (wb, ws, wm) = sort_sim(total, true, false);
    let hdfs_total = hb + hs + hm;
    let wtf_total = wb + ws + wm;

    let fig4 = ExpReport {
        id: "fig4",
        title: "total sort time (paper: HDFS >67 min, WTF <15 min, ~4x)",
        rows: vec![
            Row::new("hdfs", "total", hdfs_total, "s"),
            Row::new("wtf", "total", wtf_total, "s"),
            Row::new("speedup", "wtf/hdfs", hdfs_total / wtf_total, "x"),
        ],
        commentary: vec![format!(
            "simulated {} sort: hdfs {:.0} s vs wtf {:.0} s ({:.1}x)",
            fmt_bytes(total),
            hdfs_total,
            wtf_total,
            hdfs_total / wtf_total
        )],
    };
    let pct = |x: f64, t: f64| 100.0 * x / t;
    let fig5 = ExpReport {
        id: "fig5",
        title: "sort time by stage (paper: HDFS 91.5% shuffle; WTF 74.1% sort-stage, merge <1%)",
        rows: vec![
            Row::new("hdfs", "bucketing", hb, "s"),
            Row::new("hdfs", "sorting", hs, "s"),
            Row::new("hdfs", "merging", hm, "s"),
            Row::new("hdfs-pct", "bucketing+merging", pct(hb + hm, hdfs_total), "%"),
            Row::new("wtf", "bucketing", wb, "s"),
            Row::new("wtf", "sorting", ws, "s"),
            Row::new("wtf", "merging", wm, "s"),
            Row::new("wtf-pct", "sorting", pct(ws, wtf_total), "%"),
            Row::new("wtf-pct", "merging", pct(wm, wtf_total), "%"),
        ],
        commentary: vec![],
    };
    Ok((fig4, fig5))
}

// ====================================================================
// Figure 6 — single-server throughput vs ext4
// ====================================================================

fn fig6() -> Result<ExpReport> {
    let tb = Testbed {
        servers: 1,
        replication: 1,
        ..Testbed::default()
    };
    let chunk = 64 * MB;
    let ops = 16;

    let run_one = |mode: &str| -> f64 {
        let mut model = ClusterModel::new(tb.clone(), 1, 3);
        let (_, mk) = run_closed_loop(1, ops, |c, _, now| match mode {
            "wtf-write" => model.wtf_write(c, chunk, OpKind::SeqWrite, now),
            "wtf-read" => model.wtf_read(c, chunk, OpKind::SeqRead, now),
            "hdfs-write" => model.hdfs_write(c, chunk, now),
            "hdfs-read" => model.hdfs_seq_read(c, chunk, now),
            _ => unreachable!(),
        });
        ClusterModel::throughput(ops as u64 * chunk, mk)
    };

    let ext4_write = tb.disk_bw as f64; // raw device streaming rate
    let ext4_read = tb.disk_bw as f64;
    let rows = vec![
        Row::new("ext4", "write", ext4_write / 1e6, "MB/s"),
        Row::new("ext4", "read", ext4_read / 1e6, "MB/s"),
        Row::new("wtf", "write", run_one("wtf-write") / 1e6, "MB/s"),
        Row::new("wtf", "read", run_one("wtf-read") / 1e6, "MB/s"),
        Row::new("hdfs", "write", run_one("hdfs-write") / 1e6, "MB/s"),
        Row::new("hdfs", "read", run_one("hdfs-read") / 1e6, "MB/s"),
    ];
    Ok(ExpReport {
        id: "fig6",
        title: "single-server throughput vs ext4 (paper: max ~87 MB/s; POSIX is the ceiling)",
        rows,
        commentary: vec!["distributed systems approach but never exceed the local filesystem".into()],
    })
}

// ====================================================================
// Figures 7 & 8 — sequential writes: throughput + latency vs block size
// ====================================================================

fn write_sweep(sizes: &[u64], kind: OpKind, hdfs: bool, quick: bool) -> Vec<(u64, f64, Summary)> {
    let clients = 12;
    sizes
        .iter()
        .map(|&bytes| {
            // Fixed total volume per point so large blocks don't run for
            // tiny op counts.
            let total = if quick { 600 * MB } else { 6 * GB };
            let ops = ((total / clients as u64) / bytes).max(8) as usize;
            let mut model = ClusterModel::new(Testbed::default(), clients, bytes ^ 0xF1);
            let (lat, mk) = run_pipelined(clients, ops, |c, _, now| {
                if hdfs {
                    model.hdfs_write_op(c, bytes, now)
                } else {
                    model.wtf_write_op(c, bytes, kind, now)
                }
            });
            (
                bytes,
                ClusterModel::throughput(clients as u64 * ops as u64 * bytes, mk),
                Summary::of(&lat),
            )
        })
        .collect()
}

const WRITE_SIZES: [u64; 6] = [
    256 * 1024,
    1024 * 1024,
    4 * MB,
    8 * MB,
    16 * MB,
    64 * MB,
];

fn fig7_8(quick: bool) -> Result<(ExpReport, ExpReport)> {
    let wtf = write_sweep(&WRITE_SIZES, OpKind::SeqWrite, false, quick);
    let hdfs = write_sweep(&WRITE_SIZES, OpKind::SeqWrite, true, quick);
    let mut t_rows = Vec::new();
    let mut l_rows = Vec::new();
    for ((b, tput, lat), (_, htput, hlat)) in wtf.iter().zip(hdfs.iter()) {
        let x = fmt_bytes(*b);
        t_rows.push(Row::new("wtf", x.clone(), tput / 1e6, "MB/s"));
        t_rows.push(Row::new("hdfs", x.clone(), htput / 1e6, "MB/s"));
        t_rows.push(Row::new("ratio", x.clone(), tput / htput, "x"));
        l_rows.push(Row::new("wtf-p50", x.clone(), lat.p50 as f64 / 1e6, "ms"));
        l_rows.push(Row::new("wtf-p95", x.clone(), lat.p95 as f64 / 1e6, "ms"));
        l_rows.push(Row::new("hdfs-p50", x.clone(), hlat.p50 as f64 / 1e6, "ms"));
        l_rows.push(Row::new("hdfs-p95", x, hlat.p95 as f64 / 1e6, "ms"));
    }
    Ok((
        ExpReport {
            id: "fig7",
            title: "sequential write throughput vs block size (paper: ~400 MB/s both; WTF 97% of HDFS >=1MB, 84% at 256kB)",
            rows: t_rows,
            commentary: vec![],
        },
        ExpReport {
            id: "fig8",
            title: "write latency vs block size (paper: medians track; 3 ms HyperDex floor visible at 256 kB)",
            rows: l_rows,
            commentary: vec![],
        },
    ))
}

// ====================================================================
// Figures 9 & 10 — random writes (WTF only; HDFS cannot)
// ====================================================================

fn fig9_10(quick: bool) -> Result<(ExpReport, ExpReport)> {
    let sizes = [256 * 1024, MB, 4 * MB, 8 * MB, 16 * MB];
    let seq = write_sweep(&sizes, OpKind::SeqWrite, false, quick);
    let rand = write_sweep(&sizes, OpKind::RandWrite, false, quick);
    let mut t_rows = Vec::new();
    let mut l_rows = Vec::new();
    for ((b, st, sl), (_, rt, rl)) in seq.iter().zip(rand.iter()) {
        let x = fmt_bytes(*b);
        t_rows.push(Row::new("wtf-seq", x.clone(), st / 1e6, "MB/s"));
        t_rows.push(Row::new("wtf-rand", x.clone(), rt / 1e6, "MB/s"));
        t_rows.push(Row::new("rand/seq", x.clone(), rt / st, "x"));
        l_rows.push(Row::new("seq-p50", x.clone(), sl.p50 as f64 / 1e6, "ms"));
        l_rows.push(Row::new("seq-p99", x.clone(), sl.p99 as f64 / 1e6, "ms"));
        l_rows.push(Row::new("rand-p50", x.clone(), rl.p50 as f64 / 1e6, "ms"));
        l_rows.push(Row::new("rand-p99", x, rl.p99 as f64 / 1e6, "ms"));
    }
    Ok((
        ExpReport {
            id: "fig9",
            title: "random vs sequential write throughput (paper: within 2x, converging by 8 MB; HDFS: unsupported)",
            rows: t_rows,
            commentary: vec!["hdfs random writes: structurally impossible (append-only API)".into()],
        },
        ExpReport {
            id: "fig10",
            title: "seq vs random write latency (paper: medians equal; p99 diverges below 4 MB)",
            rows: l_rows,
            commentary: vec![],
        },
    ))
}

// ====================================================================
// Figure 11 — sequential reads
// ====================================================================

fn read_sweep(
    sizes: &[u64],
    kind: OpKind,
    hdfs: bool,
    quick: bool,
) -> Vec<(u64, f64, Summary)> {
    let clients = 12;
    sizes
        .iter()
        .map(|&bytes| {
            let total = if quick { 600 * MB } else { 6 * GB };
            let ops = ((total / clients as u64) / bytes).max(8) as usize;
            let mut model = ClusterModel::new(Testbed::default(), clients, bytes ^ 0xD00D);
            let (lat, mk) = run_closed_loop(clients, ops, |c, _, now| {
                if hdfs {
                    match kind {
                        OpKind::RandRead => model.hdfs_rand_read(c, bytes, now),
                        _ => model.hdfs_seq_read(c, bytes, now),
                    }
                } else {
                    model.wtf_read(c, bytes, kind, now)
                }
            });
            (
                bytes,
                ClusterModel::throughput(clients as u64 * ops as u64 * bytes, mk),
                Summary::of(&lat),
            )
        })
        .collect()
}

fn fig11(quick: bool) -> Result<ExpReport> {
    let sizes = [256 * 1024, MB, 4 * MB, 16 * MB, 64 * MB];
    let wtf = read_sweep(&sizes, OpKind::SeqRead, false, quick);
    let hdfs = read_sweep(&sizes, OpKind::SeqRead, true, quick);
    let mut rows = Vec::new();
    for ((b, wt, _), (_, ht, _)) in wtf.iter().zip(hdfs.iter()) {
        let x = fmt_bytes(*b);
        rows.push(Row::new("wtf", x.clone(), wt / 1e6, "MB/s"));
        rows.push(Row::new("hdfs", x.clone(), ht / 1e6, "MB/s"));
        rows.push(Row::new("ratio", x, wt / ht, "x"));
    }
    Ok(ExpReport {
        id: "fig11",
        title: "sequential read throughput (paper: ~900 MB/s; WTF >= 80% of HDFS, readahead gap at large sizes)",
        rows,
        commentary: vec![],
    })
}

// ====================================================================
// Figure 12 — random reads
// ====================================================================

fn fig12(quick: bool) -> Result<ExpReport> {
    let sizes = [256 * 1024, MB, 4 * MB, 16 * MB];
    let wtf = read_sweep(&sizes, OpKind::RandRead, false, quick);
    let hdfs = read_sweep(&sizes, OpKind::RandRead, true, quick);
    let mut rows = Vec::new();
    for ((b, wt, wl), (_, ht, hl)) in wtf.iter().zip(hdfs.iter()) {
        let x = fmt_bytes(*b);
        rows.push(Row::new("wtf", x.clone(), wt / 1e6, "MB/s"));
        rows.push(Row::new("hdfs", x.clone(), ht / 1e6, "MB/s"));
        rows.push(Row::new("ratio", x.clone(), wt / ht, "x"));
        rows.push(Row::new("wtf-p95-ms", x.clone(), wl.p95 as f64 / 1e6, "ms"));
        rows.push(Row::new("hdfs-p50-ms", x, hl.p50 as f64 / 1e6, "ms"));
    }
    Ok(ExpReport {
        id: "fig12",
        title: "random read throughput (paper: WTF up to 2.4x; readahead hurts HDFS below 16 MB)",
        rows,
        commentary: vec![],
    })
}

// ====================================================================
// Figures 13 & 14 — scaling the number of writers
// ====================================================================

fn fig13_14(quick: bool) -> Result<(ExpReport, ExpReport)> {
    let bytes = 4 * MB;
    let counts: &[usize] = if quick {
        &[1, 4, 8, 12]
    } else {
        &[1, 2, 4, 6, 8, 10, 12, 48]
    };
    let mut t_rows = Vec::new();
    let mut l_rows = Vec::new();
    for &clients in counts {
        for hdfs in [false, true] {
            let ops = if quick { 24 } else { 96 };
            let mut model = ClusterModel::new(Testbed::default(), clients, clients as u64);
            let (lat, mk) = run_pipelined(clients, ops, |c, _, now| {
                if hdfs {
                    model.hdfs_write_op(c, bytes, now)
                } else {
                    model.wtf_write_op(c, bytes, OpKind::SeqWrite, now)
                }
            });
            let tput = ClusterModel::throughput(clients as u64 * ops as u64 * bytes, mk);
            let s = Summary::of(&lat);
            let name = if hdfs { "hdfs" } else { "wtf" };
            t_rows.push(Row::new(name, clients.to_string(), tput / 1e6, "MB/s"));
            l_rows.push(Row::new(
                format!("{name}-p50"),
                clients.to_string(),
                s.p50 as f64 / 1e6,
                "ms",
            ));
            l_rows.push(Row::new(
                format!("{name}-p95"),
                clients.to_string(),
                s.p95 as f64 / 1e6,
                "ms",
            ));
        }
    }
    Ok((
        ExpReport {
            id: "fig13",
            title: "throughput vs writers (paper: ~60 MB/s @1 to ~380 MB/s @12; flat beyond)",
            rows: t_rows,
            commentary: vec![],
        },
        ExpReport {
            id: "fig14",
            title: "median write latency vs writers (latency grows as the cluster saturates)",
            rows: l_rows,
            commentary: vec![],
        },
    ))
}

// ====================================================================
// Figure 15 — garbage collection rate vs garbage fraction
// ====================================================================

fn fig15(quick: bool) -> Result<ExpReport> {
    let tb = Testbed::default();
    let agg_bw = (tb.servers as u64 * tb.disk_bw) as f64; // rewrite bandwidth
    let mut rows = Vec::new();
    for g10 in 1..=9u32 {
        let g = g10 as f64 / 10.0;
        // Sparse-file GC rewrites only the live fraction: to reclaim G
        // bytes of garbage we rewrite G*(1-g)/g live bytes (§2.8), so
        // the reclaim rate is agg_bw * g / (1 - g).
        let rate = agg_bw * g / (1.0 - g);
        rows.push(Row::new("reclaim-rate", format!("{:.0}%", g * 100.0), rate / 1e9, "GB/s"));
    }

    // Real-mode validation at small scale: measure rewritten vs
    // reclaimed on actual backing files for three garbage fractions.
    let fractions: &[u32] = if quick { &[25, 75] } else { &[10, 25, 50, 75, 90] };
    for &pct in fractions {
        let cluster = Cluster::builder().config(Config::test()).build()?;
        let c = cluster.client();
        let f = c.create("/gcfile")?;
        let block = 1024u64;
        let blocks = 64u64;
        for i in 0..blocks {
            c.write_at(f.inode(), i * block, &vec![i as u8; block as usize])?;
        }
        // Overwrite `pct`% of the blocks -> that fraction becomes garbage
        // once compacted.
        let to_overwrite = blocks * u64::from(pct) / 100;
        let mut rng = crate::util::Rng::new(u64::from(pct));
        let mut order: Vec<u64> = (0..blocks).collect();
        rng.shuffle(&mut order);
        for &i in order.iter().take(to_overwrite as usize) {
            c.write_at(f.inode(), i * block, &vec![0xAB; block as usize])?;
        }
        c.compact_file(f.inode(), usize::MAX)?;
        cluster.run_gc()?; // scan 1
        let report = cluster.run_gc()?; // scan 2 collects
        let io_eff = report.bytes_reclaimed as f64
            / (report.bytes_rewritten.max(1) + report.bytes_reclaimed) as f64;
        rows.push(Row::new(
            "real-reclaimed",
            format!("{pct}%"),
            report.bytes_reclaimed as f64 / 1024.0,
            "kB",
        ));
        rows.push(Row::new("real-reclaim-fraction", format!("{pct}%"), io_eff, "frac"));
    }

    // Steady-state overhead to stay under the watermark (§2.8: <= 4%).
    // A workload overwriting `f_ow` of its writes generates garbage at
    // rate W*f_ow; holding the disk at garbage fraction g means GC
    // rewrites (1-g)/g live bytes per garbage byte reclaimed.
    let f_ow = 0.04; // paper's workload regime
    let g_hold = 0.5;
    let overhead = f_ow * (1.0 - g_hold) / g_hold;
    rows.push(Row::new("steady-overhead", format!("{:.0}%", g_hold * 100.0), overhead * 100.0, "%"));

    Ok(ExpReport {
        id: "fig15",
        title: "GC rate vs garbage fraction (paper: >9 GB/s at 90% garbage; <=4% steady overhead)",
        rows,
        commentary: vec![
            "model: reclaim rate = disk_bw_total * g/(1-g); real-mode rows measured on actual sparse rewrites".into(),
        ],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_shape() {
        let r = table2(true).unwrap();
        // Slicing writes ~0; conventional reads 3x (300 GB for 100 GB).
        assert!(r.value("slicing-W", "total").unwrap() < 0.01);
        let conv_r = r.value("conventional-R", "total").unwrap();
        assert!((250.0..350.0).contains(&conv_r), "conv R {conv_r}");
        let slice_r = r.value("slicing-R", "total").unwrap();
        assert!((150.0..250.0).contains(&slice_r), "slice R {slice_r}");
        // Merging reads nothing under slicing.
        assert!(r.value("slicing-R", "merging").unwrap() < 0.01);
    }

    #[test]
    fn fig4_speedup_shape() {
        let r = fig4_5(true).unwrap().0;
        let speedup = r.value("speedup", "wtf/hdfs").unwrap();
        assert!(
            (2.0..8.0).contains(&speedup),
            "sort speedup {speedup} out of the paper's ~4x band"
        );
    }

    #[test]
    fn fig5_breakdown_shape() {
        let r = fig4_5(true).unwrap().1;
        let hdfs_shuffle = r.value("hdfs-pct", "bucketing+merging").unwrap();
        assert!(hdfs_shuffle > 55.0, "hdfs shuffle {hdfs_shuffle}% should dominate");
        let wtf_merge = r.value("wtf-pct", "merging").unwrap();
        assert!(wtf_merge < 5.0, "wtf merge {wtf_merge}% should be tiny");
    }

    #[test]
    fn fig6_posix_is_ceiling() {
        let r = fig6().unwrap();
        let ext4 = r.value("ext4", "write").unwrap();
        for series in ["wtf", "hdfs"] {
            for op in ["write", "read"] {
                let v = r.value(series, op).unwrap();
                assert!(v <= ext4 * 1.05, "{series} {op} {v} exceeds ext4 {ext4}");
                assert!(v > ext4 * 0.3, "{series} {op} {v} unreasonably slow");
            }
        }
    }

    #[test]
    fn fig7_shape() {
        let r = fig7_8(true).unwrap().0;
        // Ratio approaches 1 for big blocks, smaller at 256 kB.
        let small = r.value("ratio", "256.0 kB").unwrap();
        let big = r.value("ratio", "16.0 MB").unwrap();
        assert!(small < big * 1.05, "small {small} vs big {big}");
        assert!(big > 0.85 && big < 1.3, "big-block ratio {big}");
    }

    #[test]
    fn fig9_random_within_2x() {
        let r = fig9_10(true).unwrap().0;
        for v in r.series("rand/seq") {
            assert!(v >= 0.45, "rand/seq {v} below the paper's 2x bound");
        }
        let last = *r.series("rand/seq").last().unwrap();
        assert!(last > 0.8, "convergence by 8-16 MB: {last}");
    }

    #[test]
    fn fig10_p99_diverges_small_sizes_only() {
        let r = fig9_10(true).unwrap().1;
        let p50_seq = r.value("seq-p50", "1.0 MB").unwrap();
        let p50_rand = r.value("rand-p50", "1.0 MB").unwrap();
        assert!((p50_rand / p50_seq) < 1.5, "medians should track");
        let p99_rand = r.value("rand-p99", "1.0 MB").unwrap();
        let p99_seq = r.value("seq-p99", "1.0 MB").unwrap();
        assert!(p99_rand > p99_seq, "random p99 should exceed sequential");
    }

    #[test]
    fn fig12_wtf_wins_small_random_reads() {
        let r = fig12(true).unwrap();
        let small = r.value("ratio", "1.0 MB").unwrap();
        assert!(small > 1.5, "wtf/hdfs small random reads {small} (paper ~2.4x)");
        let big = r.value("ratio", "16.0 MB").unwrap();
        assert!(big < small, "advantage shrinks with size: {big} vs {small}");
    }

    #[test]
    fn fig13_scaling_shape() {
        let r = fig13_14(true).unwrap().0;
        let one = r.value("wtf", "1").unwrap();
        let twelve = r.value("wtf", "12").unwrap();
        assert!(twelve > 3.0 * one, "12 clients {twelve} should be >> 1 client {one}");
    }

    #[test]
    fn fig15_gc_rate_grows_with_garbage() {
        let r = fig15(true).unwrap();
        let rates = r.series("reclaim-rate");
        assert!(rates.windows(2).all(|w| w[0] < w[1]), "monotone: {rates:?}");
        assert!(*rates.last().unwrap() > 8.0, "90% garbage -> >8 GB/s");
        let overhead = r.value("steady-overhead", "50%").unwrap();
        assert!(overhead <= 5.0, "steady overhead {overhead}%");
        // Real rows: higher garbage fraction -> better reclaim fraction.
        let f25 = r.value("real-reclaim-fraction", "25%").unwrap();
        let f75 = r.value("real-reclaim-fraction", "75%").unwrap();
        assert!(f75 > f25, "sparse rewrite favors garbage-heavy files");
    }
}
