//! Storage servers: where slice bytes live.
//!
//! The file-slicing abstraction makes these servers radically simple
//! (§2.2): they know nothing about files, offsets, or concurrency — the
//! complete API is *create slice* and *retrieve slice*.  A server owns a
//! directory of append-only backing files; a created slice's location is
//! chosen by the server and only then returned to the writer inside a
//! self-contained [`SlicePtr`](crate::types::SlicePtr).
//!
//! * [`backing`] — append-only backing files, pread-style retrieval,
//!   sparse-rewrite garbage collection.
//! * [`server`] — the two-call server API + locality-aware backing-file
//!   selection (§2.7).
//! * [`placement`] — the consistent-hash ring that routes a region's
//!   writes to the same servers (§2.7).
//! * [`gc`] — the cluster-wide three-tier GC protocol (§2.8): scan
//!   metadata for in-use slices, two-consecutive-scan safety rule,
//!   most-garbage-first collection order.

pub mod backing;
pub mod gc;
pub mod placement;
pub mod server;

pub use gc::{GcCoordinator, GcReport};
pub use placement::Ring;
pub use server::{StorageCluster, StorageServer};
