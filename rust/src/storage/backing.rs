//! Append-only backing files.
//!
//! Each storage server maintains several backing files written strictly
//! sequentially (§2.2); a slice is `(backing, offset, len)` within one of
//! them.  Retrieval is positional (`pread`), so concurrent readers never
//! contend on a seek pointer.  Garbage collection rewrites a backing file
//! *sparsely*: live extents are copied into a fresh file at their
//! original offsets (holes where garbage was), so every live slice
//! pointer remains valid while the dead ranges stop occupying disk
//! (§2.8's sparse-file trick).

use crate::error::{Error, Result};
use crate::types::BackingId;
use std::sync::Mutex;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};

/// One append-only backing file.
#[derive(Debug)]
pub struct BackingFile {
    pub id: BackingId,
    path: PathBuf,
    inner: Mutex<Inner>,
}

#[derive(Debug)]
struct Inner {
    file: File,
    /// Logical end of the file (next append offset).
    len: u64,
    /// Bytes ever appended (monotone; survives GC rewrites).
    appended: u64,
}

impl BackingFile {
    /// Create (or truncate) a backing file at `dir/backing-<id>.dat`.
    pub fn create(dir: &Path, id: BackingId) -> Result<Self> {
        let path = dir.join(format!("backing-{id:04}.dat"));
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)?;
        Ok(BackingFile {
            id,
            path,
            inner: Mutex::new(Inner {
                file,
                len: 0,
                appended: 0,
            }),
        })
    }

    /// Append `data`, returning the offset it was written at.  Appends are
    /// strictly sequential per backing file.
    pub fn append(&self, data: &[u8]) -> Result<u64> {
        let mut g = self.inner.lock().unwrap();
        let off = g.len;
        g.file.write_all_at(data, off)?;
        g.len += data.len() as u64;
        g.appended += data.len() as u64;
        Ok(off)
    }

    /// Positional read of `len` bytes at `offset`.
    pub fn read_at(&self, offset: u64, len: u64) -> Result<Vec<u8>> {
        let g = self.inner.lock().unwrap();
        if offset + len > g.len {
            return Err(Error::InvalidArgument(format!(
                "read [{offset}, {}) beyond backing len {}",
                offset + len,
                g.len
            )));
        }
        let mut buf = vec![0u8; len as usize];
        g.file.read_exact_at(&mut buf, offset)?;
        Ok(buf)
    }

    /// Logical length (next append offset).
    pub fn len(&self) -> u64 {
        self.inner.lock().unwrap().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total bytes ever appended.
    pub fn appended(&self) -> u64 {
        self.inner.lock().unwrap().appended
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Sparse rewrite (§2.8): keep only `live` extents — sorted, disjoint
    /// `(offset, len)` pairs — at their original offsets; everything else
    /// becomes a hole.  Returns `(bytes_rewritten, bytes_reclaimed)`.
    ///
    /// Counter-intuitively, the more garbage a file holds the *cheaper*
    /// it is to collect: only live bytes are rewritten.
    pub fn sparse_rewrite(&self, live: &[(u64, u64)]) -> Result<(u64, u64)> {
        let mut g = self.inner.lock().unwrap();
        let tmp_path = self.path.with_extension("gc.tmp");
        let mut tmp = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&tmp_path)?;
        let mut rewritten = 0u64;
        let mut prev_end = 0u64;
        for &(off, len) in live {
            if len == 0 {
                continue;
            }
            if off < prev_end {
                return Err(Error::InvalidArgument(
                    "live extents must be sorted and disjoint".into(),
                ));
            }
            if off + len > g.len {
                return Err(Error::InvalidArgument(format!(
                    "live extent [{off}, {}) beyond backing len {}",
                    off + len,
                    g.len
                )));
            }
            let mut buf = vec![0u8; len as usize];
            g.file.read_exact_at(&mut buf, off)?;
            // Writing at `off` into a fresh file leaves a hole before it.
            tmp.write_all_at(&buf, off)?;
            rewritten += len;
            prev_end = off + len;
        }
        // Preserve the logical length so future appends go past old data.
        tmp.set_len(g.len)?;
        tmp.flush()?;
        std::fs::rename(&tmp_path, &self.path)?;
        let reclaimed = g.len - rewritten;
        g.file = OpenOptions::new().read(true).write(true).open(&self.path)?;
        Ok((rewritten, reclaimed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_and_read_round_trip() {
        let dir = crate::util::TempDir::new("wtf-backing-test").unwrap();
        let b = BackingFile::create(dir.path(), 0).unwrap();
        let o1 = b.append(b"hello").unwrap();
        let o2 = b.append(b"world").unwrap();
        assert_eq!((o1, o2), (0, 5));
        assert_eq!(b.read_at(0, 5).unwrap(), b"hello");
        assert_eq!(b.read_at(5, 5).unwrap(), b"world");
        assert_eq!(b.read_at(3, 4).unwrap(), b"lowo");
        assert_eq!(b.len(), 10);
    }

    #[test]
    fn read_past_end_is_an_error() {
        let dir = crate::util::TempDir::new("wtf-backing-test").unwrap();
        let b = BackingFile::create(dir.path(), 0).unwrap();
        b.append(b"abc").unwrap();
        assert!(b.read_at(1, 3).is_err());
        assert!(b.read_at(4, 0).is_err());
    }

    #[test]
    fn sparse_rewrite_keeps_live_extents_at_offsets() {
        let dir = crate::util::TempDir::new("wtf-backing-test").unwrap();
        let b = BackingFile::create(dir.path(), 0).unwrap();
        b.append(b"aaaa").unwrap(); // [0,4) garbage
        b.append(b"bbbb").unwrap(); // [4,8) live
        b.append(b"cccc").unwrap(); // [8,12) garbage
        b.append(b"dddd").unwrap(); // [12,16) live
        let (rewritten, reclaimed) = b.sparse_rewrite(&[(4, 4), (12, 4)]).unwrap();
        assert_eq!((rewritten, reclaimed), (8, 8));
        // Live data still readable at the same offsets.
        assert_eq!(b.read_at(4, 4).unwrap(), b"bbbb");
        assert_eq!(b.read_at(12, 4).unwrap(), b"dddd");
        // Length preserved; appends continue past the end.
        assert_eq!(b.len(), 16);
        let o = b.append(b"ee").unwrap();
        assert_eq!(o, 16);
        assert_eq!(b.read_at(16, 2).unwrap(), b"ee");
    }

    #[test]
    fn sparse_rewrite_all_garbage_is_cheapest() {
        let dir = crate::util::TempDir::new("wtf-backing-test").unwrap();
        let b = BackingFile::create(dir.path(), 0).unwrap();
        b.append(&vec![7u8; 4096]).unwrap();
        let (rewritten, reclaimed) = b.sparse_rewrite(&[]).unwrap();
        assert_eq!((rewritten, reclaimed), (0, 4096));
    }

    #[test]
    fn sparse_rewrite_rejects_unsorted_extents() {
        let dir = crate::util::TempDir::new("wtf-backing-test").unwrap();
        let b = BackingFile::create(dir.path(), 0).unwrap();
        b.append(&[0u8; 100]).unwrap();
        assert!(b.sparse_rewrite(&[(50, 10), (40, 20)]).is_err());
    }

    #[test]
    fn appended_counter_survives_rewrite() {
        let dir = crate::util::TempDir::new("wtf-backing-test").unwrap();
        let b = BackingFile::create(dir.path(), 0).unwrap();
        b.append(&[1u8; 64]).unwrap();
        b.sparse_rewrite(&[]).unwrap();
        assert_eq!(b.appended(), 64);
    }
}
