//! Cluster-wide garbage collection (§2.8).
//!
//! Storage servers outsource all bookkeeping to the metadata store, so
//! they cannot know locally which bytes are garbage.  The GC coordinator
//! periodically scans the entire filesystem metadata, builds the in-use
//! slice list for each storage server, and hands each server the *live*
//! extents to keep; the server sparse-rewrites its backing files around
//! them (cheapest for the most-garbaged files).
//!
//! Safety against the create-then-reference race: a byte range is only
//! collected when it was absent from **two consecutive scans** — a slice
//! created between scans is still protected by the previous scan's
//! "everything newer than my horizon is live" rule, implemented here by
//! keeping each backing's append horizon per scan and treating bytes past
//! the horizon as live.

use crate::error::Result;
use crate::meta::MetaSnapshot;
use crate::net::{Peer, Request, Transport};
use crate::types::{ServerId, SliceData, Space, Value};
use std::collections::HashMap;
use std::sync::Arc;

use super::server::StorageCluster;

/// Per-run GC accounting — Figure 15's raw numbers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GcReport {
    pub bytes_rewritten: u64,
    pub bytes_reclaimed: u64,
    pub servers_collected: u32,
}

/// Sorted, disjoint `(offset, len)` extents keyed by `(server, backing)`.
pub type InUseMap = HashMap<(ServerId, u32), Vec<(u64, u64)>>;

/// Merge raw extents into sorted, disjoint form.
pub fn normalize_extents(mut extents: Vec<(u64, u64)>) -> Vec<(u64, u64)> {
    extents.retain(|(_, l)| *l > 0);
    extents.sort_unstable();
    let mut out: Vec<(u64, u64)> = Vec::with_capacity(extents.len());
    for (off, len) in extents {
        match out.last_mut() {
            Some((loff, llen)) if off <= *loff + *llen => {
                let end = (off + len).max(*loff + *llen);
                *llen = end - *loff;
            }
            _ => out.push((off, len)),
        }
    }
    out
}

/// Union of two normalized extent lists.
pub fn union_extents(a: &[(u64, u64)], b: &[(u64, u64)]) -> Vec<(u64, u64)> {
    let mut all = a.to_vec();
    all.extend_from_slice(b);
    normalize_extents(all)
}

/// Scan the region space and build the in-use map (§2.8 first phase).
/// The paper stores these lists in a reserved WTF directory so servers
/// read them through the client library; in-process we hand the map to
/// the servers directly (DESIGN.md §5).
pub fn scan_in_use(meta: &dyn MetaSnapshot) -> Result<InUseMap> {
    scan_in_use_with_spills(meta, None, None)
}

/// Fetch the bytes behind a spill pointer — through the transport when
/// one is supplied (so GC traffic pays the same modeled wire cost as
/// client traffic), directly otherwise (unit tests).
fn fetch_spill(
    cluster: &StorageCluster,
    transport: Option<&Transport>,
    ptr: &crate::types::SlicePtr,
) -> Result<Vec<u8>> {
    let server = cluster.get(ptr.server)?;
    match transport {
        Some(t) => t
            .call(server.clone() as Peer, Request::RetrieveSlice { ptr: *ptr })?
            .into_bytes(),
        None => server.retrieve_slice(ptr),
    }
}

/// [`scan_in_use`] that also decodes tier-2 spill slices (fetched from
/// `cluster`) so the data they reference stays protected.
pub fn scan_in_use_with_spills(
    meta: &dyn MetaSnapshot,
    cluster: Option<&StorageCluster>,
    transport: Option<&Transport>,
) -> Result<InUseMap> {
    // Live inodes: regions belonging to unlinked files are garbage too
    // (§2.8: "as an application overwrites or deletes files, slices
    // become unused").  Region keys embed the inode id.  A failed scan
    // aborts the whole round: an unreadable shard must never be
    // mistaken for an empty one, or its live slices get reclaimed.
    let live_inodes: std::collections::HashSet<String> = meta
        .scan_space(Space::Inode)?
        .into_iter()
        .map(|(k, _)| k.key)
        .collect();
    let mut raw: HashMap<(ServerId, u32), Vec<(u64, u64)>> = HashMap::new();
    for (key, value) in meta.scan_space(Space::Region)? {
        let Value::Region(region) = value else {
            continue;
        };
        let inode_part = key.key.split('#').next().unwrap_or("");
        if !live_inodes.contains(inode_part) {
            continue; // orphaned region: everything it points at is dead
        }
        // The tier-2 spill slice itself is in use — and so is every
        // slice the spilled entries reference, which requires decoding
        // the spill payload.
        if let Some(replicas) = &region.spill {
            for p in replicas {
                raw.entry((p.server, p.backing))
                    .or_default()
                    .push((p.offset, p.len));
            }
            if let Some(cluster) = cluster {
                for p in replicas {
                    let Ok(bytes) = fetch_spill(cluster, transport, p) else {
                        continue;
                    };
                    if let Ok(entries) = crate::client::spill::decode_entries(&bytes) {
                        for e in entries {
                            if let SliceData::Stored(rs) = e.data {
                                for r in rs {
                                    raw.entry((r.server, r.backing))
                                        .or_default()
                                        .push((r.offset, r.len));
                                }
                            }
                        }
                        break; // one replica suffices
                    }
                }
            }
        }
        for entry in &region.entries {
            if let SliceData::Stored(replicas) = &entry.data {
                for p in replicas {
                    raw.entry((p.server, p.backing))
                        .or_default()
                        .push((p.offset, p.len));
                }
            }
        }
    }
    Ok(raw
        .into_iter()
        .map(|(k, v)| (k, normalize_extents(v)))
        .collect())
}

/// Defense-in-depth for the cache/GC coexistence rule (PR 9), validated
/// centrally by `Config::validate` and re-asserted here at every
/// scheduled round start: when the versioned metadata cache and
/// scheduled GC are both on, `cache_ttl` must be nonzero and strictly
/// below `gc_scan_interval`.  A cached region entry carries slice
/// pointers; the two-consecutive-scan rule only reclaims bytes
/// unreferenced for a full scan interval, so an entry that expires
/// inside one interval can never outlive the reclamation window and
/// hand a reader pointers into rewritten bytes.
pub fn assert_cache_ttl_bound(config: &crate::config::Config) {
    if config.metadata_cache && !config.gc_scan_interval.is_zero() {
        assert!(
            !config.cache_ttl.is_zero() && config.cache_ttl < config.gc_scan_interval,
            "cache_ttl ({:?}) must be nonzero and strictly below gc_scan_interval \
             ({:?}): a cached region entry must expire before the two-scan window \
             can reclaim the bytes it points at",
            config.cache_ttl,
            config.gc_scan_interval,
        );
    }
}

/// The periodic GC driver.
#[derive(Debug, Default)]
pub struct GcCoordinator {
    /// Previous scan's in-use map (two-consecutive-scan rule).
    previous: Option<InUseMap>,
    /// Append horizon per (server, backing) at the previous scan: bytes
    /// written after it are unconditionally live this round.
    previous_horizon: HashMap<(ServerId, u32), u64>,
}

impl GcCoordinator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run one GC round: scan metadata, protect anything live in either
    /// of the last two scans or written since the previous scan, and
    /// sparse-rewrite every backing file on every server.  Spill reads
    /// go through `transport` when supplied, so the scan pays the same
    /// modeled wire cost as any other reader.
    pub fn run(
        &mut self,
        meta: &dyn MetaSnapshot,
        cluster: &StorageCluster,
        transport: Option<&Transport>,
    ) -> Result<GcReport> {
        // An unreadable shard aborts the round before anything is
        // touched — GC must never collect against a partial scan.
        let current = scan_in_use_with_spills(meta, Some(cluster), transport)?;
        let mut report = GcReport::default();

        // First scan ever: record state, collect nothing (a slice created
        // before this scan might be referenced after it).
        let Some(previous) = self.previous.take() else {
            self.record_horizon(cluster, current);
            return Ok(report);
        };

        for server in cluster.iter() {
            let sid = server.id();
            let mut live: HashMap<u32, Vec<(u64, u64)>> = HashMap::new();
            for backing in 0..server.num_backings() {
                let cur = current
                    .get(&(sid, backing))
                    .cloned()
                    .unwrap_or_default();
                let prev = previous
                    .get(&(sid, backing))
                    .cloned()
                    .unwrap_or_default();
                let mut keep = union_extents(&cur, &prev);
                // Bytes appended after the previous scan's horizon are
                // live no matter what the metadata says (they may be
                // referenced by a transaction racing this scan).
                let horizon = self
                    .previous_horizon
                    .get(&(sid, backing))
                    .copied()
                    .unwrap_or(0);
                let end = server_backing_len(server, backing);
                if end > horizon {
                    keep = union_extents(&keep, &[(horizon, end - horizon)]);
                }
                live.insert(backing, keep);
            }
            let (rewritten, reclaimed) = server.gc_backings(&live)?;
            report.bytes_rewritten += rewritten;
            report.bytes_reclaimed += reclaimed;
            if reclaimed > 0 {
                report.servers_collected += 1;
            }
        }
        self.record_horizon(cluster, current);
        Ok(report)
    }

    fn record_horizon(&mut self, cluster: &StorageCluster, scan: InUseMap) {
        self.previous_horizon.clear();
        for server in cluster.iter() {
            for backing in 0..server.num_backings() {
                self.previous_horizon.insert(
                    (server.id(), backing),
                    server_backing_len(server, backing),
                );
            }
        }
        self.previous = Some(scan);
    }
}

fn server_backing_len(server: &Arc<crate::storage::StorageServer>, backing: u32) -> u64 {
    server.backing_len(backing)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::meta::{Commit, MetaOp, MetaStore};
    use crate::storage::StorageServer;
    use crate::types::{Key, Placement, RegionEntry, RegionId};

    #[test]
    fn normalize_merges_overlaps_and_adjacency() {
        assert_eq!(
            normalize_extents(vec![(10, 5), (0, 5), (5, 5), (30, 2), (12, 10)]),
            vec![(0, 22), (30, 2)]
        );
        assert_eq!(normalize_extents(vec![(1, 0)]), vec![]);
    }

    #[test]
    fn union_is_commutative_and_merged() {
        let a = vec![(0u64, 10u64)];
        let b = vec![(5u64, 10u64), (100, 1)];
        assert_eq!(union_extents(&a, &b), vec![(0, 15), (100, 1)]);
        assert_eq!(union_extents(&a, &b), union_extents(&b, &a));
    }

    fn cluster_with_one_server() -> (MetaStore, StorageCluster) {
        let meta = MetaStore::new(4, 1);
        let server = Arc::new(StorageServer::new(0, None, 2).unwrap());
        (meta, StorageCluster::new(vec![server]))
    }

    fn reference_in_meta(meta: &MetaStore, region: RegionId, ptr: crate::types::SlicePtr) {
        // The region's inode must exist or the scan treats it as orphaned.
        let _ = meta.commit(&Commit {
            reads: vec![],
            ops: vec![MetaOp::Put {
                key: Key::inode(region.inode),
                value: crate::types::Value::Inode(crate::types::Inode::new_file(
                    region.inode,
                    0o644,
                    1,
                )),
            }],
        });
        let c = Commit {
            reads: vec![],
            ops: vec![MetaOp::RegionAppend {
                key: Key::region(region),
                entry: RegionEntry {
                    placement: Placement::At(0),
                    len: ptr.len,
                    data: SliceData::Stored(vec![ptr]),
                },
            }],
        };
        meta.commit(&c).unwrap();
    }

    #[test]
    fn unreferenced_slices_collected_after_two_scans() {
        let (meta, cluster) = cluster_with_one_server();
        let server = cluster.get(0).unwrap().clone();
        let region = RegionId::new(1, 0);
        let live = server.create_slice(&[1u8; 128], region).unwrap();
        let _dead = server.create_slice(&[2u8; 256], region).unwrap();
        reference_in_meta(&meta, region, live);

        let mut gc = GcCoordinator::new();
        // Scan 1: records state, collects nothing.
        let r1 = gc.run(&meta, &cluster, None).unwrap();
        assert_eq!(r1.bytes_reclaimed, 0);
        // Scan 2: the dead slice was absent from both scans AND below the
        // horizon -> collected.
        let r2 = gc.run(&meta, &cluster, None).unwrap();
        assert_eq!(r2.bytes_reclaimed, 256);
        // The live slice still reads back.
        assert_eq!(
            server.retrieve_slice(&live).unwrap(),
            vec![1u8; 128]
        );
    }

    #[test]
    fn fresh_writes_survive_the_race_window() {
        let (meta, cluster) = cluster_with_one_server();
        let server = cluster.get(0).unwrap().clone();
        let region = RegionId::new(1, 0);
        let mut gc = GcCoordinator::new();
        gc.run(&meta, &cluster, None).unwrap(); // scan 1

        // Created AFTER scan 1, referenced only after scan 2 runs — the
        // exact race §2.8 defends against.
        let racing = server.create_slice(&[3u8; 64], region).unwrap();
        let r2 = gc.run(&meta, &cluster, None).unwrap();
        assert_eq!(r2.bytes_reclaimed, 0, "racing slice must survive");
        reference_in_meta(&meta, region, racing);
        assert_eq!(server.retrieve_slice(&racing).unwrap(), vec![3u8; 64]);
    }

    #[test]
    fn scan_in_use_collects_all_replicas() {
        let (meta, cluster) = cluster_with_one_server();
        let server = cluster.get(0).unwrap().clone();
        let region = RegionId::new(1, 0);
        let a = server.create_slice(&[1u8; 10], region).unwrap();
        let b = server.create_slice(&[1u8; 10], region).unwrap();
        // The inode must exist or the region counts as orphaned.
        reference_in_meta(&meta, region, a);
        let c = Commit {
            reads: vec![],
            ops: vec![MetaOp::RegionAppend {
                key: Key::region(region),
                entry: RegionEntry {
                    placement: Placement::At(10),
                    len: 10,
                    data: SliceData::Stored(vec![b]),
                },
            }],
        };
        meta.commit(&c).unwrap();
        let in_use = scan_in_use(&meta).unwrap();
        let extents = &in_use[&(0, a.backing)];
        assert_eq!(extents.iter().map(|(_, l)| l).sum::<u64>(), 20);
    }

    #[test]
    #[should_panic(expected = "strictly below gc_scan_interval")]
    fn gc_round_asserts_the_cache_ttl_bound() {
        let mut cfg = crate::config::Config::test();
        cfg.metadata_cache = true;
        cfg.gc_scan_interval = std::time::Duration::from_secs(60);
        cfg.cache_ttl = std::time::Duration::from_secs(60); // not strictly below
        assert_cache_ttl_bound(&cfg);
    }

    #[test]
    fn cache_ttl_expires_region_entries_before_reclamation() {
        // PR-9 coexistence proof in miniature: a second client's cached
        // region entry (holding slice pointers) must expire via TTL
        // before GC's two-scan window can reclaim the bytes it points
        // at.  After the overwrite + TTL + reclamation, the stale
        // client re-reads fresh metadata and observes the new bytes —
        // it never dereferences pointers into rewritten storage.
        use crate::cluster::Cluster;
        let mut cfg = crate::config::Config::fast_read_test();
        cfg.cache_ttl = std::time::Duration::from_millis(2);
        cfg.gc_scan_interval = std::time::Duration::from_secs(1);
        let cluster = Cluster::builder().config(cfg).build().unwrap();
        let c1 = cluster.client();
        let c2 = cluster.client();
        let mut fd = c1.create("/gc").unwrap();
        c1.write(&mut fd, &[b'a'; 1024]).unwrap();
        // c2 warms its own cache over the original slice.
        let rfd = c2.open("/gc").unwrap();
        assert_eq!(c2.read_at(&rfd, 0, 1024).unwrap(), vec![b'a'; 1024]);
        // c1 overwrites the whole region, then compacts it: the
        // shadowed original slice loses its last metadata reference,
        // but c2's cache still points at it.
        c1.write_at(fd.inode(), 0, &[b'b'; 1024]).unwrap();
        c1.compact_region(crate::types::RegionId::new(fd.inode(), 0))
            .unwrap();
        // TTL passes BEFORE any reclamation is possible.
        std::thread::sleep(std::time::Duration::from_millis(5));
        // Two scans reclaim the unreferenced original bytes.
        cluster.run_gc().unwrap();
        let report = cluster.run_gc().unwrap();
        assert!(
            report.bytes_reclaimed > 0,
            "overwritten slice should be reclaimed after two scans"
        );
        // c2's cached entry expired with the TTL: the read refetches
        // metadata and observes the overwrite, not reclaimed bytes.
        assert_eq!(
            c2.read_at(&rfd, 0, 1024).unwrap(),
            vec![b'b'; 1024],
            "expired cache entry must not serve pointers into reclaimed storage"
        );
    }

    #[test]
    fn empty_metadata_collects_everything_old() {
        let (meta, cluster) = cluster_with_one_server();
        let server = cluster.get(0).unwrap().clone();
        server
            .create_slice(&[0u8; 512], RegionId::new(1, 0))
            
            .unwrap();
        let mut gc = GcCoordinator::new();
        gc.run(&meta, &cluster, None).unwrap();
        let r = gc.run(&meta, &cluster, None).unwrap();
        assert_eq!(r.bytes_reclaimed, 512);
        let _ = meta; // metadata never referenced the slice
    }
}
