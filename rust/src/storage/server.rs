//! The storage server: the complete API is two calls (§2.2).
//!
//! * `create_slice(data, region_hint)` — write bytes to disk, *then*
//!   return a self-contained [`SlicePtr`].  The server has total freedom
//!   in where it puts the bytes because the pointer is minted after the
//!   write; here it uses the region hint to pick a backing file so writes
//!   to one region stay sequential on disk (§2.7).
//! * `retrieve_slice(ptr)` — follow the pointer: open the named backing
//!   file, positional-read `len` bytes.
//!
//! Servers retain no information about the filesystem structure; all
//! bookkeeping is outsourced to the metadata store.
//!
//! Clients never call these methods directly: requests arrive as
//! [`Request`] envelopes through the [`crate::net::Transport`], which
//! also charges the simulated wire cost (so a scatter of replica creates
//! overlaps their transfers).  The [`Handler`] impl below is the server
//! side of that RPC.

use super::backing::BackingFile;
use super::placement::backing_of;
use crate::error::{Error, Result};
use crate::metrics::Metrics;
use crate::net::{Handler, Peer, Request, Response};
use crate::types::{RegionId, ServerId, SlicePtr};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

/// One storage server.
#[derive(Debug)]
pub struct StorageServer {
    id: ServerId,
    /// Keeps a tempdir alive when the server owns its directory.
    _tempdir: Option<crate::util::TempDir>,
    dir: PathBuf,
    backings: Vec<Arc<BackingFile>>,
    metrics: Metrics,
}

impl StorageServer {
    /// Create a server over `dir` (a tempdir when `None`) with
    /// `num_backings` backing files.
    pub fn new(id: ServerId, dir: Option<PathBuf>, num_backings: u32) -> Result<Self> {
        let (tempdir, dir) = match dir {
            Some(d) => {
                std::fs::create_dir_all(&d)?;
                (None, d)
            }
            None => {
                let t = crate::util::TempDir::new(&format!("wtf-storage-{id}"))?;
                let p = t.path().to_path_buf();
                (Some(t), p)
            }
        };
        let backings = (0..num_backings.max(1))
            .map(|b| BackingFile::create(&dir, b).map(Arc::new))
            .collect::<Result<Vec<_>>>()?;
        Ok(StorageServer {
            id,
            _tempdir: tempdir,
            dir,
            backings,
            metrics: Metrics::new(),
        })
    }

    pub fn id(&self) -> ServerId {
        self.id
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    pub fn num_backings(&self) -> u32 {
        self.backings.len() as u32
    }

    /// Create a slice holding `data`; the `hint` names the metadata
    /// region this write belongs to, steering backing-file selection for
    /// locality (§2.7).
    pub fn create_slice(&self, data: &[u8], hint: RegionId) -> Result<SlicePtr> {
        let backing = &self.backings
            [backing_of(hint, self.id, self.backings.len() as u32) as usize];
        let offset = backing.append(data)?;
        self.metrics.add_bytes_written(data.len() as u64);
        self.metrics.add_ops_written(1);
        Ok(SlicePtr {
            server: self.id,
            backing: backing.id,
            offset,
            len: data.len() as u64,
        })
    }

    /// Retrieve the bytes a pointer refers to.
    pub fn retrieve_slice(&self, ptr: &SlicePtr) -> Result<Vec<u8>> {
        if ptr.server != self.id {
            return Err(Error::InvalidArgument(format!(
                "slice {ptr:?} routed to server {}",
                self.id
            )));
        }
        let backing = self
            .backings
            .get(ptr.backing as usize)
            .ok_or(Error::SliceNotFound {
                server: ptr.server,
                backing: ptr.backing,
                offset: ptr.offset,
                len: ptr.len,
            })?;
        let data = backing
            .read_at(ptr.offset, ptr.len)
            .map_err(|_| Error::SliceNotFound {
                server: ptr.server,
                backing: ptr.backing,
                offset: ptr.offset,
                len: ptr.len,
            })?;
        self.metrics.add_bytes_read(ptr.len);
        self.metrics.add_ops_read(1);
        Ok(data)
    }

    /// Server-side multi-get: one envelope's worth of slice fetches
    /// (the coalesced read path groups extents by server and ships a
    /// single `RetrieveMany` instead of one envelope per extent).
    /// Failures are reported per pointer — the client owns per-extent
    /// replica failover, so one bad pointer must not sink the batch.
    pub fn retrieve_many(&self, ptrs: &[SlicePtr]) -> Vec<Option<Vec<u8>>> {
        ptrs.iter().map(|p| self.retrieve_slice(p).ok()).collect()
    }

    /// Logical length of one backing file (0 for unknown ids).
    pub fn backing_len(&self, backing: u32) -> u64 {
        self.backings
            .get(backing as usize)
            .map(|b| b.len())
            .unwrap_or(0)
    }

    /// Total bytes currently occupying the logical end of all backings.
    pub fn total_len(&self) -> u64 {
        self.backings.iter().map(|b| b.len()).sum()
    }

    /// Total bytes ever appended across all backings.
    pub fn total_appended(&self) -> u64 {
        self.backings.iter().map(|b| b.appended()).sum()
    }

    /// Sparse-rewrite every backing file keeping only `live` extents;
    /// used by the GC coordinator (§2.8).  `live` maps backing id →
    /// sorted disjoint `(offset, len)` extents.  Returns
    /// `(bytes_rewritten, bytes_reclaimed)` totals.
    pub fn gc_backings(&self, live: &HashMap<u32, Vec<(u64, u64)>>) -> Result<(u64, u64)> {
        // Most-garbage-first: the file with the least live data reclaims
        // the most bytes per byte of rewrite I/O (§2.8).
        let empty: Vec<(u64, u64)> = Vec::new();
        let mut order: Vec<&Arc<BackingFile>> = self.backings.iter().collect();
        order.sort_by_key(|b| {
            let live_bytes: u64 = live
                .get(&b.id)
                .unwrap_or(&empty)
                .iter()
                .map(|(_, l)| *l)
                .sum();
            live_bytes
        });
        let mut rewritten = 0;
        let mut reclaimed = 0;
        for b in order {
            let extents = live.get(&b.id).unwrap_or(&empty);
            let (rw, rc) = b.sparse_rewrite(extents)?;
            rewritten += rw;
            reclaimed += rc;
            self.metrics.add_gc_rewritten(rw);
            self.metrics.add_gc_reclaimed(rc);
        }
        Ok((rewritten, reclaimed))
    }
}

/// The transport server side: a storage server understands exactly the
/// two data-plane envelopes its §2.2 API defines.
impl Handler for StorageServer {
    fn serve(&self, req: &Request) -> Result<Response> {
        match req {
            Request::CreateSlice { hint, data } => {
                Ok(Response::Slice(self.create_slice(data, *hint)?))
            }
            Request::RetrieveSlice { ptr } => Ok(Response::Bytes(self.retrieve_slice(ptr)?)),
            Request::RetrieveMany { ptrs } => {
                Ok(Response::BytesMany(self.retrieve_many(ptrs)))
            }
            other => Err(Error::Unsupported(format!(
                "storage server cannot serve {other:?}"
            ))),
        }
    }
}

/// The set of storage servers a client can reach, indexed by id.
/// Each id resolves to either an in-process [`StorageServer`] or (in
/// multi-process deployments) a remote transport peer — [`Self::peer`]
/// is the one lookup every data-plane envelope goes through.
#[derive(Clone, Default)]
pub struct StorageCluster {
    servers: HashMap<ServerId, Arc<StorageServer>>,
    remotes: HashMap<ServerId, Peer>,
}

impl std::fmt::Debug for StorageCluster {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StorageCluster")
            .field("servers", &self.servers)
            .field("remotes", &self.remotes.keys().collect::<Vec<_>>())
            .finish()
    }
}

impl StorageCluster {
    pub fn new(servers: Vec<Arc<StorageServer>>) -> Self {
        StorageCluster {
            servers: servers.into_iter().map(|s| (s.id(), s)).collect(),
            remotes: HashMap::new(),
        }
    }

    pub fn get(&self, id: ServerId) -> Result<&Arc<StorageServer>> {
        self.servers.get(&id).ok_or(Error::ServerUnavailable(id))
    }

    /// Register a remote peer serving server `id`'s data-plane
    /// envelopes (a [`crate::net::SocketPeer`] in the multi-process
    /// deployment).  A remote registration shadows any in-process
    /// server of the same id.
    pub fn set_remote(&mut self, id: ServerId, peer: Peer) {
        self.remotes.insert(id, peer);
    }

    /// Resolve server `id` to the transport peer that serves it:
    /// the registered remote when there is one, else the in-process
    /// server.
    pub fn peer(&self, id: ServerId) -> Result<Peer> {
        if let Some(p) = self.remotes.get(&id) {
            return Ok(p.clone());
        }
        Ok(self.get(id)?.clone() as Peer)
    }

    pub fn ids(&self) -> Vec<ServerId> {
        let mut v: Vec<ServerId> = self
            .servers
            .keys()
            .chain(self.remotes.keys())
            .copied()
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    pub fn len(&self) -> usize {
        self.ids().len()
    }

    pub fn is_empty(&self) -> bool {
        self.servers.is_empty() && self.remotes.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Arc<StorageServer>> {
        self.servers.values()
    }

    /// Remove a server (failure injection for replication tests).
    pub fn remove(&mut self, id: ServerId) -> Option<Arc<StorageServer>> {
        self.servers.remove(&id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server(id: ServerId) -> StorageServer {
        StorageServer::new(id, None, 3).unwrap()
    }

    #[test]
    fn create_then_retrieve() {
        let s = server(1);
        let hint = RegionId::new(7, 0);
        let ptr = s.create_slice(b"some bytes", hint).unwrap();
        assert_eq!(ptr.server, 1);
        assert_eq!(ptr.len, 10);
        let data = s.retrieve_slice(&ptr).unwrap();
        assert_eq!(data, b"some bytes");
        assert_eq!(s.metrics().bytes_written(), 10);
        assert_eq!(s.metrics().bytes_read(), 10);
    }

    #[test]
    fn sub_slice_retrieval_is_pure_arithmetic() {
        let s = server(1);
        let ptr = s
            .create_slice(b"0123456789", RegionId::new(1, 0))
            
            .unwrap();
        let sub = ptr.slice(3, 7);
        assert_eq!(s.retrieve_slice(&sub).unwrap(), b"3456");
    }

    #[test]
    fn same_region_appends_are_adjacent_on_disk() {
        let s = server(1);
        let hint = RegionId::new(9, 4);
        let a = s.create_slice(&[1u8; 100], hint).unwrap();
        let b = s.create_slice(&[2u8; 50], hint).unwrap();
        assert!(a.is_adjacent(&b), "{a:?} then {b:?}");
    }

    #[test]
    fn different_regions_usually_use_different_backings() {
        let s = server(1);
        let mut seen = std::collections::HashSet::new();
        for inode in 0..50u64 {
            let p = s
                .create_slice(b"x", RegionId::new(inode, 0))
                
                .unwrap();
            seen.insert(p.backing);
        }
        assert!(seen.len() > 1);
    }

    #[test]
    fn retrieval_of_bogus_pointer_fails_cleanly() {
        let s = server(1);
        let bogus = SlicePtr {
            server: 1,
            backing: 99,
            offset: 0,
            len: 4,
        };
        assert!(matches!(
            s.retrieve_slice(&bogus),
            Err(Error::SliceNotFound { .. })
        ));
        let wrong_server = SlicePtr {
            server: 2,
            backing: 0,
            offset: 0,
            len: 4,
        };
        assert!(s.retrieve_slice(&wrong_server).is_err());
    }

    #[test]
    fn handler_serves_create_and_retrieve_envelopes() {
        let s = Arc::new(server(1));
        let hint = RegionId::new(4, 0);
        let created = s
            .serve(&Request::CreateSlice {
                hint,
                data: Arc::from(&b"enveloped"[..]),
            })
            .unwrap();
        let Response::Slice(ptr) = created else {
            panic!("{created:?}")
        };
        let fetched = s.serve(&Request::RetrieveSlice { ptr }).unwrap();
        assert_eq!(fetched, Response::Bytes(b"enveloped".to_vec()));
        // Envelopes outside the storage plane are rejected.
        assert!(s
            .serve(&Request::MetaGet {
                key: crate::types::Key::sys("x")
            })
            .is_err());
    }

    #[test]
    fn retrieve_many_reports_per_pointer_failures() {
        let s = server(1);
        let hint = RegionId::new(2, 0);
        let a = s.create_slice(b"first", hint).unwrap();
        let b = s.create_slice(b"second", hint).unwrap();
        let bogus = SlicePtr {
            server: 1,
            backing: 99,
            offset: 0,
            len: 4,
        };
        let got = s.retrieve_many(&[a, bogus, b]);
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].as_deref(), Some(b"first".as_ref()));
        assert!(got[1].is_none(), "bad pointer must not sink the batch");
        assert_eq!(got[2].as_deref(), Some(b"second".as_ref()));
        // And through the envelope path.
        let resp = s
            .serve(&Request::RetrieveMany {
                ptrs: Arc::from(vec![a, b].as_slice()),
            })
            .unwrap();
        let Response::BytesMany(items) = resp else {
            panic!("{resp:?}")
        };
        assert_eq!(items.len(), 2);
        assert!(items.iter().all(|i| i.is_some()));
    }

    #[test]
    fn cluster_lookup() {
        let cluster = StorageCluster::new(vec![
            Arc::new(server(0)),
            Arc::new(server(1)),
        ]);
        assert_eq!(cluster.len(), 2);
        assert!(cluster.get(0).is_ok());
        assert!(matches!(
            cluster.get(9),
            Err(Error::ServerUnavailable(9))
        ));
        assert_eq!(cluster.ids(), vec![0, 1]);
    }

    #[test]
    fn gc_prefers_most_garbage_and_preserves_live() {
        let s = server(1);
        let hint = RegionId::new(3, 0);
        let live_ptr = s.create_slice(&[9u8; 64], hint).unwrap();
        s.create_slice(&[0u8; 192], hint).unwrap(); // garbage
        let mut live = HashMap::new();
        live.insert(live_ptr.backing, vec![(live_ptr.offset, live_ptr.len)]);
        let (rewritten, reclaimed) = s.gc_backings(&live).unwrap();
        assert_eq!(rewritten, 64);
        assert_eq!(reclaimed, 192);
        assert_eq!(s.retrieve_slice(&live_ptr).unwrap(), vec![9u8; 64]);
    }
}
