//! Locality-aware slice placement (§2.7).
//!
//! Two *different* hash functions drive placement, exactly as the paper
//! prescribes:
//!
//! 1. A consistent-hash ring across storage servers maps a metadata
//!    region to the servers holding its slices — so sequential writes to
//!    one region land on the same server, and their slices end up
//!    adjacent on disk (fusable by compaction).
//! 2. Inside each server, a *different* hash maps the region to one of
//!    the server's backing files — so two regions that collide onto one
//!    server are unlikely to interleave within one backing file.

use crate::types::{RegionId, ServerId};

/// Consistent-hash ring over the storage servers ([Karger et al. 1997]).
#[derive(Clone, Debug)]
pub struct Ring {
    /// `(point, server)` sorted by point.
    points: Vec<(u64, ServerId)>,
    servers: Vec<ServerId>,
}

fn hash64(seed: u64, bytes: &[u8]) -> u64 {
    // FNV-1a with a seed mixed in; stable across processes.
    let mut h = 0xcbf29ce484222325u64 ^ seed.wrapping_mul(0x9e3779b97f4a7c15);
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100000001b3);
    }
    // Final avalanche (splitmix64 tail) for well-spread ring points.
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58476d1ce4e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d049bb133111eb);
    h ^ (h >> 31)
}

/// Ring hash: used ACROSS servers.
fn region_point(region: RegionId) -> u64 {
    hash64(0x5eed_0001, region.key().as_bytes())
}

/// Backing-file hash: a DIFFERENT function, used WITHIN a server.
pub fn backing_of(region: RegionId, server: ServerId, num_backings: u32) -> u32 {
    let mut buf = region.key().into_bytes();
    buf.extend_from_slice(&server.to_le_bytes());
    (hash64(0x5eed_0002, &buf) % u64::from(num_backings.max(1))) as u32
}

impl Ring {
    /// Build a ring with `vnodes` virtual nodes per server.
    pub fn new(servers: &[ServerId], vnodes: u32) -> Self {
        let mut points = Vec::with_capacity(servers.len() * vnodes as usize);
        for &s in servers {
            for v in 0..vnodes.max(1) {
                let mut key = [0u8; 8];
                key[..4].copy_from_slice(&s.to_le_bytes());
                key[4..].copy_from_slice(&v.to_le_bytes());
                points.push((hash64(0x5eed_0003, &key), s));
            }
        }
        points.sort_unstable();
        let mut servers = servers.to_vec();
        servers.sort_unstable();
        servers.dedup();
        Ring { points, servers }
    }

    /// The `n` distinct servers responsible for `region`, in preference
    /// order (primary first).  `n` is capped at the number of servers.
    pub fn servers_for(&self, region: RegionId, n: usize) -> Vec<ServerId> {
        let n = n.min(self.servers.len());
        if n == 0 || self.points.is_empty() {
            return Vec::new();
        }
        let point = region_point(region);
        let start = self
            .points
            .partition_point(|(p, _)| *p < point)
            .min(self.points.len().saturating_sub(1));
        let mut out = Vec::with_capacity(n);
        for i in 0..self.points.len() {
            let (_, s) = self.points[(start + i) % self.points.len()];
            if !out.contains(&s) {
                out.push(s);
                if out.len() == n {
                    break;
                }
            }
        }
        out
    }

    pub fn servers(&self) -> &[ServerId] {
        &self.servers
    }

    pub fn is_empty(&self) -> bool {
        self.servers.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn ring(n: u32) -> Ring {
        Ring::new(&(0..n).collect::<Vec<_>>(), 64)
    }

    #[test]
    fn placement_is_deterministic() {
        let r = ring(12);
        let a = r.servers_for(RegionId::new(42, 7), 2);
        let b = r.servers_for(RegionId::new(42, 7), 2);
        assert_eq!(a, b);
        assert_eq!(a.len(), 2);
        assert_ne!(a[0], a[1]);
    }

    #[test]
    fn same_region_same_servers_different_regions_spread() {
        let r = ring(12);
        let mut primaries: HashMap<ServerId, usize> = HashMap::new();
        for inode in 0..50u64 {
            for idx in 0..20u32 {
                let p = r.servers_for(RegionId::new(inode, idx), 1)[0];
                *primaries.entry(p).or_default() += 1;
            }
        }
        // Every server should get a reasonable share of 1000 regions.
        assert_eq!(primaries.len(), 12);
        for (_, count) in primaries {
            assert!(count > 20, "placement badly skewed: {count}");
        }
    }

    #[test]
    fn replicas_are_distinct_and_capped() {
        let r = ring(3);
        let s = r.servers_for(RegionId::new(1, 0), 5);
        assert_eq!(s.len(), 3);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 3);
    }

    #[test]
    fn ring_membership_change_moves_few_regions() {
        let before = ring(12);
        let servers: Vec<ServerId> = (0..13).collect();
        let after = Ring::new(&servers, 64);
        let total = 1000;
        let mut moved = 0;
        for i in 0..total {
            let region = RegionId::new(i, 0);
            if before.servers_for(region, 1) != after.servers_for(region, 1) {
                moved += 1;
            }
        }
        // Consistent hashing: ~1/13 of regions move; allow generous slack.
        assert!(moved < total / 4, "too many regions moved: {moved}");
        assert!(moved > 0);
    }

    #[test]
    fn backing_hash_differs_from_ring_hash() {
        // Regions placed on the same server should spread across backings.
        let r = ring(4);
        let mut backings = std::collections::HashSet::new();
        for inode in 0..200u64 {
            let region = RegionId::new(inode, 0);
            let primary = r.servers_for(region, 1)[0];
            backings.insert(backing_of(region, primary, 4));
        }
        assert_eq!(backings.len(), 4);
    }

    #[test]
    fn empty_ring_yields_nothing() {
        let r = Ring::new(&[], 8);
        assert!(r.servers_for(RegionId::new(1, 0), 2).is_empty());
        assert!(r.is_empty());
    }
}
