//! Real-TCP transport: [`SocketPeer`] (client side), [`SocketServer`]
//! (server side), and [`SocketBridge`] (the loopback interposer that
//! lets every in-process test rerun over real sockets unchanged).
//!
//! The wire format is the [`codec`](super::codec) envelope: one
//! CRC-framed request per round, answered by one CRC-framed
//! `Result<Response>`.  Requests on one connection are strictly
//! sequential (send → reply), and a [`SocketPeer`] keeps a small pool
//! of idle connections so concurrent callers fan out over parallel
//! streams instead of serializing.
//!
//! Failure semantics are deliberately conservative:
//!
//! * A connect failure is retried once (the "reconnect" of a pool whose
//!   server restarted); if it still fails the call returns
//!   [`Error::Timeout`].
//! * Any failure after the request bytes may have left this process —
//!   a write error, a dropped connection, a truncated or corrupt reply
//!   frame — returns [`Error::Timeout`]: the outcome is UNKNOWN and the
//!   caller's indeterminate-outcome discipline (PR 5/PR 8) applies.
//!   The connection is discarded, never re-pooled.
//! * The server drops a connection whose request frame fails CRC or
//!   decode WITHOUT dispatching anything: a corrupt envelope can abort
//!   a connection but can never execute half-decoded.
//! * A handler panic on the server side also drops the connection
//!   without a reply — over a real wire, a crashed server and a lost
//!   ack are the same observable event.

use super::codec::{decode_request, decode_result, encode_request, encode_result, read_frame,
    write_frame, Frame};
use super::transport::{Handler, Peer, Request, Response};
use crate::error::{Error, Result};
use std::collections::HashMap;
use std::io::Write as IoWrite;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Idle connections kept per peer; callers beyond this open fresh
/// streams that are simply dropped after use.
const POOL_CAP: usize = 8;

/// Blocking-read bound per reply.  Healthy handlers answer in
/// microseconds; this is a last-resort hang breaker (CI), not a tuning
/// knob — when it fires the call resolves to the same indeterminate
/// [`Error::Timeout`] as a dead connection.
const READ_TIMEOUT: Duration = Duration::from_secs(60);

// ---------------------------------------------------------------------
// Client side.
// ---------------------------------------------------------------------

/// A remote [`Handler`]: RPCs to `addr` over pooled TCP connections.
pub struct SocketPeer {
    addr: Mutex<String>,
    pool: Mutex<Vec<TcpStream>>,
}

impl std::fmt::Debug for SocketPeer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SocketPeer").field("addr", &self.addr()).finish()
    }
}

impl SocketPeer {
    /// A peer for the server listening at `addr` (e.g. `127.0.0.1:7070`).
    /// Connections are opened lazily, on first use.
    pub fn new(addr: impl Into<String>) -> SocketPeer {
        SocketPeer {
            addr: Mutex::new(addr.into()),
            pool: Mutex::new(Vec::new()),
        }
    }

    pub fn addr(&self) -> String {
        self.addr.lock().unwrap().clone()
    }

    /// Re-point this peer at a new address (the process it addressed
    /// restarted under a different — typically ephemeral — port).  The
    /// idle pool is discarded: every pooled stream belongs to the old
    /// process.  In-flight calls racing this keep their old streams and
    /// resolve to the usual indeterminate [`Error::Timeout`].
    pub fn set_addr(&self, addr: impl Into<String>) {
        *self.addr.lock().unwrap() = addr.into();
        self.pool.lock().unwrap().clear();
    }

    fn connect(&self) -> std::io::Result<TcpStream> {
        let addr = self.addr();
        let dial = || -> std::io::Result<TcpStream> {
            let s = TcpStream::connect(&addr)?;
            s.set_nodelay(true)?;
            s.set_read_timeout(Some(READ_TIMEOUT))?;
            Ok(s)
        };
        dial().or_else(|_| {
            // Reconnect path: one brief grace for a restarting server.
            std::thread::sleep(Duration::from_millis(20));
            dial()
        })
    }

    /// One request/reply exchange on `stream`.  The outer error is a
    /// transport failure (indeterminate); the inner result is whatever
    /// the remote handler actually served.
    fn round_trip(stream: &mut TcpStream, payload: &[u8]) -> Result<Result<Response>> {
        write_frame(stream, payload).map_err(Error::Io)?;
        match read_frame(stream)? {
            Frame::Payload(reply) => decode_result(&reply),
            Frame::Eof => Err(Error::CorruptMetadata(
                "connection closed before reply".to_string(),
            )),
        }
    }
}

impl Handler for SocketPeer {
    fn serve(&self, req: &Request) -> Result<Response> {
        let start = Instant::now();
        let payload = encode_request(req);
        let stream = self.pool.lock().unwrap().pop();
        let mut stream = match stream {
            Some(s) => s,
            None => match self.connect() {
                Ok(s) => s,
                // Could not even open a connection: nothing was sent,
                // but callers classify through the same indeterminate
                // timeout a dead wire produces (over-conservative and
                // therefore safe).
                Err(_) => {
                    return Err(Error::Timeout {
                        op: req.op_name(),
                        elapsed: start.elapsed(),
                    })
                }
            },
        };
        match Self::round_trip(&mut stream, &payload) {
            Ok(result) => {
                let mut pool = self.pool.lock().unwrap();
                if pool.len() < POOL_CAP {
                    pool.push(stream);
                }
                result
            }
            // The request may have executed remotely: outcome unknown.
            Err(_) => Err(Error::Timeout {
                op: req.op_name(),
                elapsed: start.elapsed(),
            }),
        }
    }
}

// ---------------------------------------------------------------------
// Server side.
// ---------------------------------------------------------------------

/// A TCP listener dispatching framed envelopes to one [`Handler`].
/// Dropping the server stops the accept loop.
pub struct SocketServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl std::fmt::Debug for SocketServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SocketServer").field("addr", &self.addr).finish()
    }
}

impl SocketServer {
    /// Bind `bind` (use port 0 for an ephemeral port — the bound address
    /// is [`SocketServer::addr`]) and serve `handler` until dropped.
    pub fn serve(handler: Peer, bind: &str) -> std::io::Result<SocketServer> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let stop = Arc::clone(&shutdown);
        let accept_thread = std::thread::Builder::new()
            .name(format!("wtf-socket-{}", addr.port()))
            .spawn(move || loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if stop.load(Ordering::Relaxed) {
                            return;
                        }
                        let handler = handler.clone();
                        let _ = std::thread::Builder::new()
                            .name("wtf-socket-conn".to_string())
                            .spawn(move || Self::connection(stream, handler));
                    }
                    Err(_) => {
                        if stop.load(Ordering::Relaxed) {
                            return;
                        }
                    }
                }
            })?;
        Ok(SocketServer {
            addr,
            shutdown,
            accept_thread: Some(accept_thread),
        })
    }

    /// The bound listen address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve one connection: `[request frame] → [Result<Response> frame]`
    /// rounds until EOF.  Any framing/decode failure drops the
    /// connection with NOTHING dispatched for that frame; a handler
    /// panic drops it without a reply (fail-stop over the wire).
    fn connection(mut stream: TcpStream, handler: Peer) {
        let _ = stream.set_nodelay(true);
        loop {
            let payload = match read_frame(&mut stream) {
                Ok(Frame::Payload(p)) => p,
                Ok(Frame::Eof) | Err(_) => return,
            };
            let req = match decode_request(&payload) {
                Ok(r) => r,
                // Corrupt envelope: kill the connection, dispatch nothing.
                Err(_) => return,
            };
            let served = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                handler.serve(&req)
            }));
            let result = match served {
                Ok(r) => r,
                Err(_) => return,
            };
            if write_frame(&mut stream, &encode_result(&result)).is_err() {
                return;
            }
        }
    }
}

impl Drop for SocketServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // Unblock the accept loop with a throwaway connection.
        if let Ok(mut s) = TcpStream::connect(self.addr) {
            let _ = s.flush();
        }
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

// ---------------------------------------------------------------------
// Loopback bridge: run any in-process peer behind a real socket.
// ---------------------------------------------------------------------

/// Routes in-process peers through per-peer loopback socket pairs, so
/// the whole test suite (chaos schedules included) exercises the real
/// framing, connection pool, and failure mapping without changing a
/// line of test code.  Installed by `Transport` when
/// `WTF_SOCKET_TRANSPORT=1`; keyed by peer identity exactly like the
/// turbulence layer, and interposed AFTER turbulence decides an
/// envelope's fate, so seeded fault schedules stay byte-identical.
pub struct SocketBridge {
    routes: Mutex<HashMap<usize, (SocketServer, Peer)>>,
}

impl std::fmt::Debug for SocketBridge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SocketBridge").finish()
    }
}

impl SocketBridge {
    pub fn new() -> SocketBridge {
        SocketBridge {
            routes: Mutex::new(HashMap::new()),
        }
    }

    /// The socket-backed stand-in for `peer`, lazily booting a loopback
    /// server around it.  The original peer Arc is retained by its
    /// server, so the identity key can never be recycled while routed.
    /// If the loopback cannot bind, the call degrades to the in-process
    /// peer (never wrong, just not exercising the wire).
    pub(crate) fn route(&self, peer: &Peer) -> Peer {
        let key = Arc::as_ptr(peer) as *const () as usize;
        let mut routes = self.routes.lock().unwrap();
        if let Some((_, p)) = routes.get(&key) {
            return p.clone();
        }
        match SocketServer::serve(peer.clone(), "127.0.0.1:0") {
            Ok(server) => {
                let remote: Peer = Arc::new(SocketPeer::new(server.addr().to_string()));
                routes.insert(key, (server, remote.clone()));
                remote
            }
            Err(_) => peer.clone(),
        }
    }
}

impl Default for SocketBridge {
    fn default() -> Self {
        SocketBridge::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkModel;
    use crate::net::Transport;
    use std::sync::atomic::AtomicU64;

    struct Echo {
        calls: AtomicU64,
    }

    impl Handler for Echo {
        fn serve(&self, req: &Request) -> Result<Response> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            match req {
                Request::ReadBlock { len, .. } => Ok(Response::Bytes(vec![9u8; *len as usize])),
                Request::AppendBlock { data, .. } => Ok(Response::BlockLen(data.len() as u64)),
                _ => Err(Error::Unsupported("echo".into())),
            }
        }
    }

    fn echo() -> Arc<Echo> {
        Arc::new(Echo {
            calls: AtomicU64::new(0),
        })
    }

    #[test]
    fn socket_round_trip_and_typed_errors() {
        let e = echo();
        let server = SocketServer::serve(e.clone(), "127.0.0.1:0").unwrap();
        let peer = SocketPeer::new(server.addr().to_string());
        let resp = peer
            .serve(&Request::ReadBlock {
                block: 0,
                offset: 0,
                len: 5,
            })
            .unwrap();
        assert!(matches!(resp, Response::Bytes(ref b) if b == &vec![9u8; 5]));
        // A typed handler error crosses the wire as the same variant.
        let err = peer.serve(&Request::PaxosStatus { shard: 0 }).unwrap_err();
        assert!(matches!(err, Error::Unsupported(_)), "{err}");
        assert_eq!(e.calls.load(Ordering::Relaxed), 2);
    }

    /// The no-partial-dispatch guarantee, end to end: a corrupt frame
    /// kills the connection and the handler never runs, while the
    /// server keeps serving fresh connections.
    #[test]
    fn corrupt_frame_drops_connection_without_dispatch() {
        let e = echo();
        let server = SocketServer::serve(e.clone(), "127.0.0.1:0").unwrap();
        let mut raw = TcpStream::connect(server.addr()).unwrap();
        // A well-formed header whose CRC does not match its payload.
        let payload = encode_request(&Request::ReadBlock {
            block: 0,
            offset: 0,
            len: 1,
        });
        let mut framed = Vec::new();
        write_frame(&mut framed, &payload).unwrap();
        let crc_at = 4;
        framed[crc_at] ^= 0xFF;
        raw.write_all(&framed).unwrap();
        raw.flush().unwrap();
        // The server must close the connection without replying...
        let mut reply = [0u8; 1];
        use std::io::Read as _;
        raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        assert_eq!(raw.read(&mut reply).unwrap_or(0), 0, "expected EOF");
        // ...having dispatched nothing...
        assert_eq!(e.calls.load(Ordering::Relaxed), 0);
        // ...and still serve a healthy peer afterwards.
        let peer = SocketPeer::new(server.addr().to_string());
        peer.serve(&Request::ReadBlock {
            block: 0,
            offset: 0,
            len: 1,
        })
        .unwrap();
        assert_eq!(e.calls.load(Ordering::Relaxed), 1);
    }

    /// A dead server maps to the indeterminate timeout class — the
    /// caller cannot know whether its envelope executed.
    #[test]
    fn dead_server_maps_to_indeterminate_timeout() {
        let e = echo();
        let server = SocketServer::serve(e.clone(), "127.0.0.1:0").unwrap();
        let addr = server.addr().to_string();
        let peer = SocketPeer::new(addr);
        let req = Request::ReadBlock {
            block: 0,
            offset: 0,
            len: 1,
        };
        peer.serve(&req).unwrap();
        drop(server); // SIGKILL stand-in: listener gone, pooled conn dead.
        let err = peer.serve(&req).unwrap_err();
        assert!(err.is_indeterminate(), "{err}");
    }

    /// A peer re-pointed at a restarted server's new ephemeral address
    /// drops its stale pool and serves again (the multi-process test's
    /// respawn handshake).
    #[test]
    fn set_addr_repoints_a_peer_at_a_respawned_server() {
        let e1 = echo();
        let s1 = SocketServer::serve(e1.clone(), "127.0.0.1:0").unwrap();
        let peer = SocketPeer::new(s1.addr().to_string());
        let req = Request::ReadBlock {
            block: 0,
            offset: 0,
            len: 1,
        };
        peer.serve(&req).unwrap(); // pool now holds a stream into s1
        drop(s1);
        let e2 = echo();
        let s2 = SocketServer::serve(e2.clone(), "127.0.0.1:0").unwrap();
        peer.set_addr(s2.addr().to_string());
        peer.serve(&req).unwrap();
        assert_eq!(e1.calls.load(Ordering::Relaxed), 1);
        assert_eq!(e2.calls.load(Ordering::Relaxed), 1);
    }

    /// The loopback bridge: an ordinary in-process transport call runs
    /// over a real socket pair with identical results.
    #[test]
    fn bridged_transport_round_trips() {
        let t = Transport::socket_bridged(LinkModel::instant(), 0);
        assert!(t.is_socket_bridged());
        let e = echo();
        let resp = t
            .call(
                e.clone(),
                Request::ReadBlock {
                    block: 0,
                    offset: 0,
                    len: 3,
                },
            )
            .unwrap();
        assert!(matches!(resp, Response::Bytes(ref b) if b.len() == 3));
        // Same peer again: the route (and its connection pool) is reused.
        t.call(
            e.clone(),
            Request::AppendBlock {
                block: 0,
                data: Arc::from(vec![1u8, 2].into_boxed_slice()),
            },
        )
        .unwrap();
        assert_eq!(e.calls.load(Ordering::Relaxed), 2);
    }
}
