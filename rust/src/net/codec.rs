//! Wire codec for [`Request`]/[`Response`] envelopes — the socket
//! transport's framing layer.
//!
//! Reuses the WAL's framing discipline byte for byte: every envelope
//! travels as `[len: u32 LE][crc32: u32 LE][payload]` with the same
//! IEEE CRC-32 and the same 64 MB frame bound, and the payload codec is
//! built from the WAL's hand-rolled little-endian helpers (`put_*`,
//! [`Dec`]) so the two on-wire formats cannot drift apart in dialect.
//! A truncated or bit-flipped frame decodes to a typed
//! [`Error::CorruptMetadata`] on the reader — never a partial value —
//! and the socket layer drops the connection without dispatching
//! anything (a corrupt request must not execute half-decoded).
//!
//! Responses travel as a full `Result<Response>`: a remote handler's
//! typed error is re-materialized on the caller so failover logic
//! (`is_retryable` / `is_indeterminate` classification) behaves
//! identically under both transports.  One lossy corner is `Error::Io`,
//! which flattens to its display string, and `Error::Timeout { op }`,
//! whose `&'static str` op is re-interned from the fixed operation-name
//! set (unknown names fall back to `"remote"`).

use crate::error::{Error, Result};
use crate::meta::wal::{
    crc32, dec_ballot, dec_entry, dec_key, dec_op, dec_opt_value, dec_outcomes, dec_slice_ptr,
    dec_slice_ptrs, dec_space, enc_ballot, enc_entry, enc_key, enc_op, enc_opt_value, enc_outcomes,
    enc_slice_ptr, enc_slice_ptrs, enc_space, put_blob, put_bool, put_str, put_u32, put_u64, put_u8,
    Corrupt, Dec,
};
use crate::meta::Commit;
use crate::net::{Request, Response};
use crate::types::RegionId;
use std::io::{Read as IoRead, Write as IoWrite};
use std::sync::Arc;
use std::time::Duration;

/// Upper bound on one framed envelope payload — matches the WAL's
/// discipline: anything larger is corruption, not an allocation request.
pub const MAX_FRAME: u32 = 64 << 20;

// ---------------------------------------------------------------------
// Frame I/O: [len u32 LE][crc32 u32 LE][payload].
// ---------------------------------------------------------------------

/// Write one CRC-framed payload to `w`.
pub fn write_frame(w: &mut impl IoWrite, payload: &[u8]) -> std::io::Result<()> {
    let mut head = [0u8; 8];
    head[..4].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    head[4..].copy_from_slice(&crc32(payload).to_le_bytes());
    w.write_all(&head)?;
    w.write_all(payload)?;
    w.flush()
}

/// What one blocking frame read produced.
#[derive(Debug)]
pub enum Frame {
    /// A complete, CRC-verified payload.
    Payload(Vec<u8>),
    /// Clean EOF before any header byte — the peer closed the
    /// connection between envelopes.
    Eof,
}

/// Read one CRC-framed payload from `r`.  A short read mid-frame, a
/// CRC mismatch, or an oversized length all return a typed error (the
/// socket layer treats any of them as a dead connection).
pub fn read_frame(r: &mut impl IoRead) -> Result<Frame> {
    let mut head = [0u8; 8];
    let mut got = 0;
    while got < head.len() {
        let n = r.read(&mut head[got..]).map_err(Error::Io)?;
        if n == 0 {
            if got == 0 {
                return Ok(Frame::Eof);
            }
            return Err(Error::CorruptMetadata(format!(
                "socket frame truncated: {got} of 8 header bytes"
            )));
        }
        got += n;
    }
    let len = u32::from_le_bytes(head[..4].try_into().unwrap());
    let crc = u32::from_le_bytes(head[4..].try_into().unwrap());
    if len > MAX_FRAME {
        return Err(Error::CorruptMetadata(format!(
            "socket frame length {len} exceeds MAX_FRAME"
        )));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)
        .map_err(|e| Error::CorruptMetadata(format!("socket frame truncated mid-payload: {e}")))?;
    if crc32(&payload) != crc {
        return Err(Error::CorruptMetadata(
            "socket frame CRC mismatch".to_string(),
        ));
    }
    Ok(Frame::Payload(payload))
}

fn corrupt(c: Corrupt) -> Error {
    Error::CorruptMetadata(format!("socket envelope: {c}"))
}

// ---------------------------------------------------------------------
// Request payload codec.
// ---------------------------------------------------------------------

fn enc_commit(o: &mut Vec<u8>, c: &Commit) {
    put_u32(o, c.reads.len() as u32);
    for (k, v) in &c.reads {
        enc_key(o, k);
        put_u64(o, *v);
    }
    put_u32(o, c.ops.len() as u32);
    for op in &c.ops {
        enc_op(o, op);
    }
}

fn dec_commit(d: &mut Dec) -> std::result::Result<Commit, Corrupt> {
    let n = d.seq()?;
    let mut reads = Vec::with_capacity(n);
    for _ in 0..n {
        let k = dec_key(d)?;
        reads.push((k, d.u64()?));
    }
    let n = d.seq()?;
    let mut ops = Vec::with_capacity(n);
    for _ in 0..n {
        ops.push(dec_op(d)?);
    }
    Ok(Commit { reads, ops })
}

/// Encode one request envelope payload.
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut o = Vec::with_capacity(64);
    match req {
        Request::CreateSlice { hint, data } => {
            put_u8(&mut o, 0);
            put_u64(&mut o, hint.inode);
            put_u32(&mut o, hint.index);
            put_blob(&mut o, data);
        }
        Request::RetrieveSlice { ptr } => {
            put_u8(&mut o, 1);
            enc_slice_ptr(&mut o, ptr);
        }
        Request::RetrieveMany { ptrs } => {
            put_u8(&mut o, 2);
            enc_slice_ptrs(&mut o, ptrs);
        }
        Request::AppendBlock { block, data } => {
            put_u8(&mut o, 3);
            put_u64(&mut o, *block);
            put_blob(&mut o, data);
        }
        Request::ReadBlock { block, offset, len } => {
            put_u8(&mut o, 4);
            put_u64(&mut o, *block);
            put_u64(&mut o, *offset);
            put_u64(&mut o, *len);
        }
        Request::MetaCommit { commit } => {
            put_u8(&mut o, 5);
            enc_commit(&mut o, commit);
        }
        Request::MetaGet { key } => {
            put_u8(&mut o, 6);
            enc_key(&mut o, key);
        }
        Request::PaxosPrepare {
            shard,
            slot,
            ballot,
        } => {
            put_u8(&mut o, 7);
            put_u32(&mut o, *shard);
            put_u64(&mut o, *slot);
            enc_ballot(&mut o, ballot);
        }
        Request::PaxosAccept {
            shard,
            slot,
            ballot,
            entry,
        } => {
            put_u8(&mut o, 8);
            put_u32(&mut o, *shard);
            put_u64(&mut o, *slot);
            enc_ballot(&mut o, ballot);
            enc_entry(&mut o, entry);
        }
        Request::PaxosLearn { shard, slot, entry } => {
            put_u8(&mut o, 9);
            put_u32(&mut o, *shard);
            put_u64(&mut o, *slot);
            enc_entry(&mut o, entry);
        }
        Request::PaxosStatus { shard } => {
            put_u8(&mut o, 10);
            put_u32(&mut o, *shard);
        }
        Request::PaxosPull { shard, from } => {
            put_u8(&mut o, 11);
            put_u32(&mut o, *shard);
            put_u64(&mut o, *from);
        }
        Request::LeaseRequest {
            shard,
            leader,
            until_ms,
            epoch,
        } => {
            put_u8(&mut o, 12);
            put_u32(&mut o, *shard);
            put_u32(&mut o, *leader);
            put_u64(&mut o, *until_ms);
            put_u64(&mut o, *epoch);
        }
    }
    o
}

/// Decode one request envelope payload (strict: trailing bytes are
/// corruption).
pub fn decode_request(payload: &[u8]) -> Result<Request> {
    let mut d = Dec::new(payload);
    let req = decode_request_inner(&mut d).map_err(corrupt)?;
    d.done().map_err(corrupt)?;
    Ok(req)
}

fn decode_request_inner(d: &mut Dec) -> std::result::Result<Request, Corrupt> {
    Ok(match d.u8()? {
        0 => Request::CreateSlice {
            hint: RegionId {
                inode: d.u64()?,
                index: d.u32()?,
            },
            data: Arc::from(d.blob()?.into_boxed_slice()),
        },
        1 => Request::RetrieveSlice {
            ptr: dec_slice_ptr(d)?,
        },
        2 => Request::RetrieveMany {
            ptrs: Arc::from(dec_slice_ptrs(d)?.into_boxed_slice()),
        },
        3 => Request::AppendBlock {
            block: d.u64()?,
            data: Arc::from(d.blob()?.into_boxed_slice()),
        },
        4 => Request::ReadBlock {
            block: d.u64()?,
            offset: d.u64()?,
            len: d.u64()?,
        },
        5 => Request::MetaCommit {
            commit: dec_commit(d)?,
        },
        6 => Request::MetaGet { key: dec_key(d)? },
        7 => Request::PaxosPrepare {
            shard: d.u32()?,
            slot: d.u64()?,
            ballot: dec_ballot(d)?,
        },
        8 => Request::PaxosAccept {
            shard: d.u32()?,
            slot: d.u64()?,
            ballot: dec_ballot(d)?,
            entry: dec_entry(d)?,
        },
        9 => Request::PaxosLearn {
            shard: d.u32()?,
            slot: d.u64()?,
            entry: dec_entry(d)?,
        },
        10 => Request::PaxosStatus { shard: d.u32()? },
        11 => Request::PaxosPull {
            shard: d.u32()?,
            from: d.u64()?,
        },
        12 => Request::LeaseRequest {
            shard: d.u32()?,
            leader: d.u32()?,
            until_ms: d.u64()?,
            epoch: d.u64()?,
        },
        t => return Err(format!("invalid Request tag {t}")),
    })
}

// ---------------------------------------------------------------------
// Result<Response> payload codec.
// ---------------------------------------------------------------------

fn enc_response(o: &mut Vec<u8>, resp: &Response) {
    match resp {
        Response::Slice(ptr) => {
            put_u8(o, 0);
            enc_slice_ptr(o, ptr);
        }
        Response::Bytes(b) => {
            put_u8(o, 1);
            put_blob(o, b);
        }
        Response::BytesMany(items) => {
            put_u8(o, 2);
            put_u32(o, items.len() as u32);
            for item in items {
                match item {
                    Some(b) => {
                        put_u8(o, 1);
                        put_blob(o, b);
                    }
                    None => put_u8(o, 0),
                }
            }
        }
        Response::BlockLen(n) => {
            put_u8(o, 3);
            put_u64(o, *n);
        }
        Response::Outcomes(ocs) => {
            put_u8(o, 4);
            enc_outcomes(o, ocs);
        }
        Response::MetaValue { value, version } => {
            put_u8(o, 5);
            enc_opt_value(o, value);
            put_u64(o, *version);
        }
        Response::Promised { granted, accepted } => {
            put_u8(o, 6);
            put_bool(o, *granted);
            match accepted {
                Some((b, e)) => {
                    put_u8(o, 1);
                    enc_ballot(o, b);
                    enc_entry(o, e);
                }
                None => put_u8(o, 0),
            }
        }
        Response::Accepted(ok) => {
            put_u8(o, 7);
            put_bool(o, *ok);
        }
        Response::Learned => put_u8(o, 8),
        Response::LogLen(n) => {
            put_u8(o, 9);
            put_u64(o, *n);
        }
        Response::LogSuffix(entries) => {
            put_u8(o, 10);
            put_u32(o, entries.len() as u32);
            for e in entries {
                enc_entry(o, e);
            }
        }
        Response::LeaseGranted(ok) => {
            put_u8(o, 11);
            put_bool(o, *ok);
        }
    }
}

fn dec_response(d: &mut Dec) -> std::result::Result<Response, Corrupt> {
    Ok(match d.u8()? {
        0 => Response::Slice(dec_slice_ptr(d)?),
        1 => Response::Bytes(d.blob()?),
        2 => {
            let n = d.seq()?;
            let mut items = Vec::with_capacity(n);
            for _ in 0..n {
                items.push(match d.u8()? {
                    0 => None,
                    1 => Some(d.blob()?),
                    t => return Err(format!("invalid BytesMany tag {t}")),
                });
            }
            Response::BytesMany(items)
        }
        3 => Response::BlockLen(d.u64()?),
        4 => Response::Outcomes(dec_outcomes(d)?),
        5 => Response::MetaValue {
            value: dec_opt_value(d)?,
            version: d.u64()?,
        },
        6 => Response::Promised {
            granted: d.bool()?,
            accepted: match d.u8()? {
                0 => None,
                1 => Some((dec_ballot(d)?, dec_entry(d)?)),
                t => return Err(format!("invalid Promised tag {t}")),
            },
        },
        7 => Response::Accepted(d.bool()?),
        8 => Response::Learned,
        9 => Response::LogLen(d.u64()?),
        10 => {
            let n = d.seq()?;
            let mut entries = Vec::with_capacity(n);
            for _ in 0..n {
                entries.push(dec_entry(d)?);
            }
            Response::LogSuffix(entries)
        }
        11 => Response::LeaseGranted(d.bool()?),
        t => return Err(format!("invalid Response tag {t}")),
    })
}

/// Re-intern an operation name decoded off the wire into the fixed
/// `&'static str` set `Error::Timeout { op }` requires.
fn intern_op(name: &str) -> &'static str {
    for known in [
        "CreateSlice",
        "RetrieveSlice",
        "RetrieveMany",
        "AppendBlock",
        "ReadBlock",
        "MetaCommit",
        "MetaGet",
        "PaxosPrepare",
        "PaxosAccept",
        "PaxosLearn",
        "PaxosStatus",
        "PaxosPull",
        "LeaseRequest",
        "commit",
        "read",
    ] {
        if name == known {
            return known;
        }
    }
    "remote"
}

fn enc_error(o: &mut Vec<u8>, e: &Error) {
    match e {
        Error::TxnConflict { space, key } => {
            put_u8(o, 0);
            enc_space(o, *space);
            put_str(o, key);
        }
        Error::CondAppendFailed { eof, len, cap } => {
            put_u8(o, 1);
            put_u64(o, *eof);
            put_u64(o, *len);
            put_u64(o, *cap);
        }
        Error::TxnAborted { reason } => {
            put_u8(o, 2);
            put_str(o, reason);
        }
        Error::RetriesExhausted { attempts } => {
            put_u8(o, 3);
            put_u32(o, *attempts);
        }
        Error::Timeout { op, elapsed } => {
            put_u8(o, 4);
            put_str(o, op);
            put_u64(o, elapsed.as_nanos() as u64);
        }
        Error::NotFound(p) => {
            put_u8(o, 5);
            put_str(o, p);
        }
        Error::AlreadyExists(p) => {
            put_u8(o, 6);
            put_str(o, p);
        }
        Error::IsDirectory(p) => {
            put_u8(o, 7);
            put_str(o, p);
        }
        Error::NotADirectory(p) => {
            put_u8(o, 8);
            put_str(o, p);
        }
        Error::DirectoryNotEmpty(p) => {
            put_u8(o, 9);
            put_str(o, p);
        }
        Error::InvalidArgument(m) => {
            put_u8(o, 10);
            put_str(o, m);
        }
        Error::Unsupported(m) => {
            put_u8(o, 11);
            put_str(o, m);
        }
        Error::ServerUnavailable(id) => {
            put_u8(o, 12);
            put_u32(o, *id);
        }
        Error::SliceNotFound {
            server,
            backing,
            offset,
            len,
        } => {
            put_u8(o, 13);
            put_u32(o, *server);
            put_u32(o, *backing);
            put_u64(o, *offset);
            put_u64(o, *len);
        }
        Error::CorruptMetadata(m) => {
            put_u8(o, 14);
            put_str(o, m);
        }
        Error::NoQuorum { alive, total } => {
            put_u8(o, 15);
            put_u64(o, *alive as u64);
            put_u64(o, *total as u64);
        }
        Error::NotLeader { shard, hint } => {
            put_u8(o, 16);
            put_u32(o, *shard);
            match hint {
                Some(h) => {
                    put_u8(o, 1);
                    put_u32(o, *h);
                }
                None => put_u8(o, 0),
            }
        }
        Error::ReplicaLost { shard, replica } => {
            put_u8(o, 17);
            put_u32(o, *shard);
            put_u32(o, *replica);
        }
        Error::WalCorrupt {
            shard,
            replica,
            detail,
        } => {
            put_u8(o, 18);
            put_u32(o, *shard);
            put_u32(o, *replica);
            put_str(o, detail);
        }
        Error::Artifact(m) => {
            put_u8(o, 19);
            put_str(o, m);
        }
        Error::Xla(m) => {
            put_u8(o, 20);
            put_str(o, m);
        }
        Error::Io(e) => {
            put_u8(o, 21);
            put_str(o, &e.to_string());
        }
    }
}

fn dec_error(d: &mut Dec) -> std::result::Result<Error, Corrupt> {
    Ok(match d.u8()? {
        0 => Error::TxnConflict {
            space: dec_space(d)?,
            key: d.str()?,
        },
        1 => Error::CondAppendFailed {
            eof: d.u64()?,
            len: d.u64()?,
            cap: d.u64()?,
        },
        2 => Error::TxnAborted { reason: d.str()? },
        3 => Error::RetriesExhausted { attempts: d.u32()? },
        4 => Error::Timeout {
            op: intern_op(&d.str()?),
            elapsed: Duration::from_nanos(d.u64()?),
        },
        5 => Error::NotFound(d.str()?),
        6 => Error::AlreadyExists(d.str()?),
        7 => Error::IsDirectory(d.str()?),
        8 => Error::NotADirectory(d.str()?),
        9 => Error::DirectoryNotEmpty(d.str()?),
        10 => Error::InvalidArgument(d.str()?),
        11 => Error::Unsupported(d.str()?),
        12 => Error::ServerUnavailable(d.u32()?),
        13 => Error::SliceNotFound {
            server: d.u32()?,
            backing: d.u32()?,
            offset: d.u64()?,
            len: d.u64()?,
        },
        14 => Error::CorruptMetadata(d.str()?),
        15 => Error::NoQuorum {
            alive: d.u64()? as usize,
            total: d.u64()? as usize,
        },
        16 => Error::NotLeader {
            shard: d.u32()?,
            hint: match d.u8()? {
                0 => None,
                1 => Some(d.u32()?),
                t => return Err(format!("invalid NotLeader tag {t}")),
            },
        },
        17 => Error::ReplicaLost {
            shard: d.u32()?,
            replica: d.u32()?,
        },
        18 => Error::WalCorrupt {
            shard: d.u32()?,
            replica: d.u32()?,
            detail: d.str()?,
        },
        19 => Error::Artifact(d.str()?),
        20 => Error::Xla(d.str()?),
        21 => Error::Io(std::io::Error::new(std::io::ErrorKind::Other, d.str()?)),
        t => return Err(format!("invalid Error tag {t}")),
    })
}

/// Encode one response payload — the full served `Result`, so typed
/// errors cross the wire.
pub fn encode_result(res: &Result<Response>) -> Vec<u8> {
    let mut o = Vec::with_capacity(64);
    match res {
        Ok(resp) => {
            put_u8(&mut o, 0);
            enc_response(&mut o, resp);
        }
        Err(e) => {
            put_u8(&mut o, 1);
            enc_error(&mut o, e);
        }
    }
    o
}

/// Decode one response payload (strict: trailing bytes are corruption).
pub fn decode_result(payload: &[u8]) -> Result<Result<Response>> {
    let mut d = Dec::new(payload);
    let res = match d.u8().map_err(corrupt)? {
        0 => Ok(dec_response(&mut d).map_err(corrupt)?),
        1 => Err(dec_error(&mut d).map_err(corrupt)?),
        t => return Err(corrupt(format!("invalid Result tag {t}"))),
    };
    d.done().map_err(corrupt)?;
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::paxos::Ballot;
    use crate::meta::{EntryKind, LogEntry, MetaOp, OpOutcome};
    use crate::types::{Key, SlicePtr, Space, Value};
    use crate::util::rng::Rng;

    fn ptr(r: &mut Rng) -> SlicePtr {
        SlicePtr {
            server: r.next_u64() as u32,
            backing: r.next_u64() as u32,
            offset: r.next_u64(),
            len: r.next_u64(),
        }
    }

    fn key(r: &mut Rng) -> Key {
        let space = match r.next_below(5) {
            0 => Space::Path,
            1 => Space::Inode,
            2 => Space::Region,
            3 => Space::Dir,
            _ => Space::Sys,
        };
        Key {
            space,
            key: format!("k{:x}", r.next_u64()),
        }
    }

    fn blob(r: &mut Rng, max: usize) -> Vec<u8> {
        let mut b = vec![0u8; r.next_below(max as u64 + 1) as usize];
        r.fill_bytes(&mut b);
        b
    }

    fn entry(r: &mut Rng, depth: u32) -> LogEntry {
        let reads = vec![(key(r), r.next_u64())];
        let ops = vec![
            MetaOp::Put {
                key: key(r),
                value: Value::U64(r.next_u64()),
            },
            MetaOp::Delete { key: key(r) },
            MetaOp::DirInsert {
                key: key(r),
                name: format!("n{:x}", r.next_u64()),
                inode: r.next_u64(),
                expect_absent: r.next_below(2) == 0,
            },
        ];
        let kind = match if depth == 0 { r.next_below(3) } else { r.next_below(4) } {
            0 => EntryKind::Apply,
            1 => EntryKind::Prepare {
                participants: vec![0, 1, 2],
                coordinator: 0,
            },
            2 => EntryKind::Decide {
                commit: r.next_below(2) == 0,
            },
            _ => EntryKind::Batch(vec![entry(r, 0), entry(r, 0)]),
        };
        LogEntry {
            txn_id: r.next_u64(),
            reads,
            ops,
            kind,
        }
    }

    /// Every `Request` variant, fields seeded from `r`.
    fn all_requests(r: &mut Rng) -> Vec<Request> {
        vec![
            Request::CreateSlice {
                hint: RegionId {
                    inode: r.next_u64(),
                    index: r.next_u64() as u32,
                },
                data: Arc::from(blob(r, 64).into_boxed_slice()),
            },
            Request::RetrieveSlice { ptr: ptr(r) },
            Request::RetrieveMany {
                ptrs: Arc::from(vec![ptr(r), ptr(r), ptr(r)].into_boxed_slice()),
            },
            Request::AppendBlock {
                block: r.next_u64(),
                data: Arc::from(blob(r, 64).into_boxed_slice()),
            },
            Request::ReadBlock {
                block: r.next_u64(),
                offset: r.next_u64(),
                len: r.next_u64(),
            },
            Request::MetaCommit {
                commit: Commit {
                    reads: vec![(key(r), r.next_u64())],
                    ops: vec![MetaOp::Put {
                        key: key(r),
                        value: Value::Bytes(blob(r, 32)),
                    }],
                },
            },
            Request::MetaGet { key: key(r) },
            Request::PaxosPrepare {
                shard: r.next_u64() as u32,
                slot: r.next_u64(),
                ballot: Ballot {
                    round: r.next_u64(),
                    proposer: r.next_u64() as u32,
                },
            },
            Request::PaxosAccept {
                shard: r.next_u64() as u32,
                slot: r.next_u64(),
                ballot: Ballot {
                    round: r.next_u64(),
                    proposer: r.next_u64() as u32,
                },
                entry: entry(r, 1),
            },
            Request::PaxosLearn {
                shard: r.next_u64() as u32,
                slot: r.next_u64(),
                entry: entry(r, 1),
            },
            Request::PaxosStatus {
                shard: r.next_u64() as u32,
            },
            Request::PaxosPull {
                shard: r.next_u64() as u32,
                from: r.next_u64(),
            },
            Request::LeaseRequest {
                shard: r.next_u64() as u32,
                leader: r.next_u64() as u32,
                until_ms: r.next_u64(),
                epoch: r.next_u64(),
            },
        ]
    }

    /// Every `Response` variant, fields seeded from `r`.
    fn all_responses(r: &mut Rng) -> Vec<Response> {
        vec![
            Response::Slice(ptr(r)),
            Response::Bytes(blob(r, 64)),
            Response::BytesMany(vec![Some(blob(r, 16)), None, Some(blob(r, 16))]),
            Response::BlockLen(r.next_u64()),
            Response::Outcomes(vec![OpOutcome::Done, OpOutcome::AppendedAt(r.next_u64())]),
            Response::MetaValue {
                value: Some(Value::U64(r.next_u64())),
                version: r.next_u64(),
            },
            Response::Promised {
                granted: true,
                accepted: Some((
                    Ballot {
                        round: r.next_u64(),
                        proposer: r.next_u64() as u32,
                    },
                    entry(r, 1),
                )),
            },
            Response::Accepted(r.next_below(2) == 0),
            Response::Learned,
            Response::LogLen(r.next_u64()),
            Response::LogSuffix(vec![entry(r, 1), entry(r, 1)]),
            Response::LeaseGranted(r.next_below(2) == 0),
        ]
    }

    /// Every `Error` variant the wire codec must carry.
    fn all_errors() -> Vec<Error> {
        vec![
            Error::TxnConflict {
                space: Space::Inode,
                key: "k".into(),
            },
            Error::CondAppendFailed {
                eof: 1,
                len: 2,
                cap: 3,
            },
            Error::TxnAborted { reason: "r".into() },
            Error::RetriesExhausted { attempts: 9 },
            Error::Timeout {
                op: "PaxosAccept",
                elapsed: Duration::from_micros(1234),
            },
            Error::NotFound("/p".into()),
            Error::AlreadyExists("/p".into()),
            Error::IsDirectory("/p".into()),
            Error::NotADirectory("/p".into()),
            Error::DirectoryNotEmpty("/p".into()),
            Error::InvalidArgument("m".into()),
            Error::Unsupported("m".into()),
            Error::ServerUnavailable(3),
            Error::SliceNotFound {
                server: 1,
                backing: 2,
                offset: 3,
                len: 4,
            },
            Error::CorruptMetadata("m".into()),
            Error::NoQuorum { alive: 1, total: 3 },
            Error::NotLeader {
                shard: 2,
                hint: Some(1),
            },
            Error::NotLeader {
                shard: 2,
                hint: None,
            },
            Error::ReplicaLost {
                shard: 1,
                replica: 2,
            },
            Error::WalCorrupt {
                shard: 1,
                replica: 2,
                detail: "d".into(),
            },
            Error::Artifact("m".into()),
            Error::Xla("m".into()),
            Error::Io(std::io::Error::new(std::io::ErrorKind::Other, "io")),
        ]
    }

    /// Roundtrip identity is checked structurally via re-encoding: the
    /// envelope types deliberately do not implement `PartialEq`.
    #[test]
    fn request_roundtrip_over_all_variants() {
        for seed in [1u64, 7, 1234, 99] {
            let mut r = Rng::new(seed);
            for req in all_requests(&mut r) {
                let bytes = encode_request(&req);
                let back = decode_request(&bytes).expect("roundtrip decode");
                assert_eq!(encode_request(&back), bytes, "{}", req.op_name());
                assert_eq!(back.op_name(), req.op_name());
            }
        }
    }

    #[test]
    fn result_roundtrip_over_all_variants() {
        for seed in [1u64, 7, 1234, 99] {
            let mut r = Rng::new(seed);
            for resp in all_responses(&mut r) {
                let bytes = encode_result(&Ok(resp));
                let back = decode_result(&bytes).expect("roundtrip decode");
                assert_eq!(encode_result(&back), bytes);
            }
        }
        for err in all_errors() {
            let bytes = encode_result(&Err(err));
            let back = decode_result(&bytes).expect("roundtrip decode");
            assert_eq!(encode_result(&back), bytes);
            assert!(back.is_err());
        }
    }

    /// Errors must keep their retry/indeterminacy CLASS across the
    /// wire — that classification drives commit-path safety.
    #[test]
    fn error_classification_survives_the_wire() {
        for err in all_errors() {
            let retryable = err.is_retryable();
            let indeterminate = err.is_indeterminate();
            let back = decode_result(&encode_result(&Err(err))).unwrap().unwrap_err();
            assert_eq!(back.is_retryable(), retryable, "{back}");
            assert_eq!(back.is_indeterminate(), indeterminate, "{back}");
        }
    }

    #[test]
    fn truncated_frames_are_typed_errors() {
        let mut r = Rng::new(7);
        let req = &all_requests(&mut r)[5]; // MetaCommit: nested payload
        let mut framed = Vec::new();
        write_frame(&mut framed, &encode_request(req)).unwrap();
        for cut in 1..framed.len() {
            let mut reader = &framed[..cut];
            match read_frame(&mut reader) {
                Err(Error::CorruptMetadata(_)) => {}
                Ok(Frame::Eof) => panic!("cut {cut}: truncation misread as clean EOF"),
                other => panic!("cut {cut}: expected CorruptMetadata, got {other:?}"),
            }
        }
        // Zero bytes IS a clean EOF (peer closed between envelopes).
        let mut empty: &[u8] = &[];
        assert!(matches!(read_frame(&mut empty), Ok(Frame::Eof)));
    }

    #[test]
    fn bit_flips_never_decode() {
        let mut r = Rng::new(1234);
        for req in all_requests(&mut r) {
            let mut framed = Vec::new();
            write_frame(&mut framed, &encode_request(&req)).unwrap();
            // Flip one bit at a seeded sample of positions (every
            // position for small frames).
            let stride = (framed.len() / 64).max(1);
            for byte in (0..framed.len()).step_by(stride) {
                let mut bad = framed.clone();
                bad[byte] ^= 1 << (byte % 8);
                let mut reader = &bad[..];
                let outcome = read_frame(&mut reader).and_then(|f| match f {
                    Frame::Payload(p) => decode_request(&p).map(|_| ()),
                    Frame::Eof => Ok(()),
                });
                assert!(
                    outcome.is_err(),
                    "bit flip at byte {byte} of {} decoded cleanly",
                    req.op_name()
                );
            }
        }
    }

    /// A payload truncated BELOW the framing layer (framing intact,
    /// payload cut) must fail decode, not yield a partial request.
    #[test]
    fn truncated_payloads_never_partially_decode() {
        let mut r = Rng::new(99);
        for req in all_requests(&mut r) {
            let payload = encode_request(&req);
            for cut in 0..payload.len() {
                assert!(
                    decode_request(&payload[..cut]).is_err(),
                    "prefix {cut} of {} decoded",
                    req.op_name()
                );
            }
        }
    }
}
