//! The `Transport` abstraction: request/response envelopes, an
//! in-process worker-pool implementation, and a scatter-gather
//! `broadcast`/`join` API.
//!
//! Every cross-component call in the stack — slice creates/retrieves on
//! the storage servers, block I/O on the hdfs-lite data nodes, and
//! metadata transactions — travels as a [`Request`] envelope addressed to
//! a [`Handler`] (the server side of the RPC).  The transport executes
//! envelopes on a pool of worker threads and charges the simulated
//! [`LinkModel`] cost *on the worker*, not on the caller: a caller that
//! scatters `r` replica uploads with [`Transport::broadcast`] pays ~one
//! wire time for all of them instead of `r` serial wire times.  This is
//! the mechanism behind the paper's §2.1 observation that slices are
//! invisible until the metadata commit — all slice uploads for one
//! operation are safely concurrent.
//!
//! Call patterns:
//!
//! * [`Transport::call`] — one envelope, synchronous (send + join).
//! * [`Transport::send`] → [`Pending::join`] — asynchronous issue; the
//!   caller overlaps its own work (or other sends) with the wire time.
//! * [`Transport::broadcast`] — scatter a batch of `(destination,
//!   envelope)` pairs, then gather every result in order.  Partial
//!   failures come back as per-envelope `Err`s so callers can fail over
//!   (e.g. retry a replica create on the next ring candidate).
//!
//! With `workers == 0` the transport degrades to inline execution on the
//! caller thread — semantically identical, just serial (the pre-transport
//! behavior).

use super::chaos::{Delivery, Turbulence};
use super::LinkModel;
use crate::coordinator::paxos::Ballot;
use crate::error::{Error, Result};
use crate::meta::{Commit, LogEntry, OpOutcome};
use crate::types::{Key, RegionId, SlicePtr, Value};
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};

/// A request envelope.  Payload-bearing variants share their bytes via
/// `Arc` so a broadcast to `r` replicas does not copy the data `r` times.
#[derive(Clone)]
pub enum Request {
    /// Store `data` as a new slice; `hint` steers backing-file selection
    /// for locality (§2.7).  Served by a storage server.
    CreateSlice { hint: RegionId, data: Arc<[u8]> },
    /// Fetch the bytes behind a slice pointer.  Served by a storage
    /// server.
    RetrieveSlice { ptr: SlicePtr },
    /// Fetch the bytes behind MANY slice pointers in one envelope — the
    /// per-server half of the client's coalesced fetch plan
    /// (`Config::read_coalescing`).  Served by a storage server;
    /// per-pointer failures come back as `None` so the caller can fail
    /// that extent over to another replica without losing the batch.
    RetrieveMany { ptrs: Arc<[SlicePtr]> },
    /// Append to an hdfs-lite block (baseline data node).
    AppendBlock { block: u64, data: Arc<[u8]> },
    /// Positional read from an hdfs-lite block (baseline data node).
    ReadBlock { block: u64, offset: u64, len: u64 },
    /// Commit a metadata transaction (read-set validation + ops).
    MetaCommit { commit: Commit },
    /// Versioned metadata point read.
    MetaGet { key: Key },
    /// Paxos phase 1 for one shard-group log slot.  Served by a
    /// [`crate::meta::GroupReplica`].
    PaxosPrepare {
        shard: u32,
        slot: u64,
        ballot: Ballot,
    },
    /// Paxos phase 2: accept `entry` at `slot` unless promised higher.
    PaxosAccept {
        shard: u32,
        slot: u64,
        ballot: Ballot,
        entry: LogEntry,
    },
    /// Teach a replica a chosen entry (it appends and applies in order).
    PaxosLearn {
        shard: u32,
        slot: u64,
        entry: LogEntry,
    },
    /// A replica's chosen-log length (leader catch-up after election).
    PaxosStatus { shard: u32 },
    /// Chosen-log suffix from slot `from` (rejoining-replica replay).
    PaxosPull { shard: u32, from: u64 },
    /// Ask a replica to grant `leader` a lease until `until_ms`.
    /// `epoch` stamps the grant round: replicas refuse to honor an epoch
    /// they have already answered, so a duplicated or delayed-then-
    /// redelivered grant can never extend a lease (see
    /// [`crate::coordinator::lease::GrantState::grant`]).
    LeaseRequest {
        shard: u32,
        leader: u32,
        until_ms: u64,
        epoch: u64,
    },
}

impl fmt::Debug for Request {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Request::CreateSlice { hint, data } => {
                write!(f, "CreateSlice({:?}, {} B)", hint, data.len())
            }
            Request::RetrieveSlice { ptr } => write!(f, "RetrieveSlice({ptr:?})"),
            Request::RetrieveMany { ptrs } => write!(
                f,
                "RetrieveMany({} ptrs, {} B)",
                ptrs.len(),
                ptrs.iter().map(|p| p.len).sum::<u64>()
            ),
            Request::AppendBlock { block, data } => {
                write!(f, "AppendBlock(blk_{block:x}, {} B)", data.len())
            }
            Request::ReadBlock { block, offset, len } => {
                write!(f, "ReadBlock(blk_{block:x}, {offset}+{len})")
            }
            Request::MetaCommit { commit } => {
                write!(f, "MetaCommit({} ops)", commit.ops.len())
            }
            Request::MetaGet { key } => write!(f, "MetaGet({:?}:{})", key.space, key.key),
            Request::PaxosPrepare { shard, slot, ballot } => {
                write!(f, "PaxosPrepare(shard {shard}, slot {slot}, {ballot:?})")
            }
            Request::PaxosAccept {
                shard,
                slot,
                ballot,
                entry,
            } => write!(
                f,
                "PaxosAccept(shard {shard}, slot {slot}, {ballot:?}, txn {})",
                entry.txn_id
            ),
            Request::PaxosLearn { shard, slot, entry } => write!(
                f,
                "PaxosLearn(shard {shard}, slot {slot}, txn {})",
                entry.txn_id
            ),
            Request::PaxosStatus { shard } => write!(f, "PaxosStatus(shard {shard})"),
            Request::PaxosPull { shard, from } => {
                write!(f, "PaxosPull(shard {shard}, from {from})")
            }
            Request::LeaseRequest {
                shard,
                leader,
                until_ms,
                epoch,
            } => write!(
                f,
                "LeaseRequest(shard {shard}, leader {leader}, until {until_ms} ms, epoch {epoch})"
            ),
        }
    }
}

/// The wire direction that carries this request's payload.  The link is
/// charged exactly once per envelope, payload-sized — matching the
/// pre-transport cost model where each storage op slept once.
enum WireCost {
    /// Payload travels caller → server (charged before serving).
    Upload(u64),
    /// Payload travels server → caller (charged after serving, sized by
    /// the response).
    Download,
    /// Metadata plane: modeled by the metadata service's own transaction
    /// floor, never by the data-plane link.
    Free,
}

/// Which plane an envelope belongs to, for the per-kind counters next
/// to [`Transport::envelopes_sent`] — benches report metadata, data,
/// and Paxos traffic separately (the write-path ratios compare Paxos
/// rounds, which total counts alone cannot isolate).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Plane {
    /// Slice/block payload traffic (the storage servers).
    Data,
    /// Client-facing metadata envelopes (`MetaCommit` / `MetaGet`).
    Meta,
    /// Consensus traffic between a shard group's front-end and its
    /// replicas (prepare/accept/learn/status/pull/lease).
    Paxos,
}

impl Request {
    pub(crate) fn plane(&self) -> Plane {
        match self {
            Request::CreateSlice { .. }
            | Request::RetrieveSlice { .. }
            | Request::RetrieveMany { .. }
            | Request::AppendBlock { .. }
            | Request::ReadBlock { .. } => Plane::Data,
            Request::MetaCommit { .. } | Request::MetaGet { .. } => Plane::Meta,
            Request::PaxosPrepare { .. }
            | Request::PaxosAccept { .. }
            | Request::PaxosLearn { .. }
            | Request::PaxosStatus { .. }
            | Request::PaxosPull { .. }
            | Request::LeaseRequest { .. } => Plane::Paxos,
        }
    }

    fn wire_cost(&self) -> WireCost {
        match self {
            Request::CreateSlice { data, .. } => WireCost::Upload(data.len() as u64),
            Request::AppendBlock { data, .. } => WireCost::Upload(data.len() as u64),
            Request::RetrieveSlice { .. }
            | Request::RetrieveMany { .. }
            | Request::ReadBlock { .. } => WireCost::Download,
            Request::MetaCommit { .. }
            | Request::MetaGet { .. }
            | Request::PaxosPrepare { .. }
            | Request::PaxosAccept { .. }
            | Request::PaxosLearn { .. }
            | Request::PaxosStatus { .. }
            | Request::PaxosPull { .. }
            | Request::LeaseRequest { .. } => WireCost::Free,
        }
    }

    /// The shard this envelope addresses, when it is shard-scoped
    /// (Paxos-plane traffic) — lets turbulence rules target one group.
    pub(crate) fn shard(&self) -> Option<u32> {
        match self {
            Request::PaxosPrepare { shard, .. }
            | Request::PaxosAccept { shard, .. }
            | Request::PaxosLearn { shard, .. }
            | Request::PaxosStatus { shard }
            | Request::PaxosPull { shard, .. }
            | Request::LeaseRequest { shard, .. } => Some(*shard),
            _ => None,
        }
    }

    /// Stable operation name for typed timeouts injected by the
    /// turbulence layer.
    pub(crate) fn op_name(&self) -> &'static str {
        match self {
            Request::CreateSlice { .. } => "CreateSlice",
            Request::RetrieveSlice { .. } => "RetrieveSlice",
            Request::RetrieveMany { .. } => "RetrieveMany",
            Request::AppendBlock { .. } => "AppendBlock",
            Request::ReadBlock { .. } => "ReadBlock",
            Request::MetaCommit { .. } => "MetaCommit",
            Request::MetaGet { .. } => "MetaGet",
            Request::PaxosPrepare { .. } => "PaxosPrepare",
            Request::PaxosAccept { .. } => "PaxosAccept",
            Request::PaxosLearn { .. } => "PaxosLearn",
            Request::PaxosStatus { .. } => "PaxosStatus",
            Request::PaxosPull { .. } => "PaxosPull",
            Request::LeaseRequest { .. } => "LeaseRequest",
        }
    }
}

/// A response envelope.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// `CreateSlice`: the minted, self-contained pointer.
    Slice(SlicePtr),
    /// `RetrieveSlice` / `ReadBlock`: the payload bytes.
    Bytes(Vec<u8>),
    /// `RetrieveMany`: one payload per requested pointer, in request
    /// order; `None` marks a pointer the server could not serve (the
    /// caller fails that extent over to another replica).
    BytesMany(Vec<Option<Vec<u8>>>),
    /// `AppendBlock`: the block's new visible length.
    BlockLen(u64),
    /// `MetaCommit`: one outcome per op.
    Outcomes(Vec<OpOutcome>),
    /// `MetaGet`: current value plus the key's version — carried even
    /// for absent keys (version of absence matters to read sets; a
    /// separate version round-trip would race concurrent commits).
    MetaValue {
        value: Option<Value>,
        version: u64,
    },
    /// `PaxosPrepare`: promise granted? plus any previously accepted
    /// entry the proposer must adopt.
    Promised {
        granted: bool,
        accepted: Option<(Ballot, LogEntry)>,
    },
    /// `PaxosAccept`: accepted under the offered ballot?
    Accepted(bool),
    /// `PaxosLearn`: acknowledged.
    Learned,
    /// `PaxosStatus`: the replica's chosen-log length.
    LogLen(u64),
    /// `PaxosPull`: chosen entries from the requested slot on.
    LogSuffix(Vec<LogEntry>),
    /// `LeaseRequest`: grant outcome.
    LeaseGranted(bool),
}

impl Response {
    fn payload_len(&self) -> u64 {
        match self {
            Response::Bytes(b) => b.len() as u64,
            Response::BytesMany(items) => items
                .iter()
                .flatten()
                .map(|b| b.len() as u64)
                .sum(),
            _ => 0,
        }
    }

    /// Unwrap helpers — a mismatched variant is a protocol bug.
    pub fn into_slice(self) -> Result<SlicePtr> {
        match self {
            Response::Slice(p) => Ok(p),
            other => Err(protocol_error("Slice", &other)),
        }
    }

    pub fn into_bytes(self) -> Result<Vec<u8>> {
        match self {
            Response::Bytes(b) => Ok(b),
            other => Err(protocol_error("Bytes", &other)),
        }
    }

    pub fn into_bytes_many(self) -> Result<Vec<Option<Vec<u8>>>> {
        match self {
            Response::BytesMany(v) => Ok(v),
            other => Err(protocol_error("BytesMany", &other)),
        }
    }

    pub fn into_block_len(self) -> Result<u64> {
        match self {
            Response::BlockLen(n) => Ok(n),
            other => Err(protocol_error("BlockLen", &other)),
        }
    }

    pub fn into_outcomes(self) -> Result<Vec<OpOutcome>> {
        match self {
            Response::Outcomes(o) => Ok(o),
            other => Err(protocol_error("Outcomes", &other)),
        }
    }

    pub fn into_meta_value(self) -> Result<(Option<Value>, u64)> {
        match self {
            Response::MetaValue { value, version } => Ok((value, version)),
            other => Err(protocol_error("MetaValue", &other)),
        }
    }

    pub fn into_promised(self) -> Result<(bool, Option<(Ballot, LogEntry)>)> {
        match self {
            Response::Promised { granted, accepted } => Ok((granted, accepted)),
            other => Err(protocol_error("Promised", &other)),
        }
    }

    pub fn into_accepted(self) -> Result<bool> {
        match self {
            Response::Accepted(ok) => Ok(ok),
            other => Err(protocol_error("Accepted", &other)),
        }
    }

    pub fn into_log_len(self) -> Result<u64> {
        match self {
            Response::LogLen(n) => Ok(n),
            other => Err(protocol_error("LogLen", &other)),
        }
    }

    pub fn into_log_suffix(self) -> Result<Vec<LogEntry>> {
        match self {
            Response::LogSuffix(v) => Ok(v),
            other => Err(protocol_error("LogSuffix", &other)),
        }
    }

    pub fn into_lease_granted(self) -> Result<bool> {
        match self {
            Response::LeaseGranted(ok) => Ok(ok),
            other => Err(protocol_error("LeaseGranted", &other)),
        }
    }
}

fn protocol_error(expected: &str, got: &Response) -> Error {
    Error::CorruptMetadata(format!(
        "transport protocol violation: expected {expected}, got {got:?}"
    ))
}

/// Run a metadata-plane handler body fail-stop: a panic becomes a typed
/// [`Error::ReplicaLost`] for (`shard`, `replica`) instead of being
/// resumed on the joining caller.  Metadata replicas are quorum members —
/// one crashing must merely degrade its group's quorum, not poison the
/// client thread that happened to scatter a Paxos round to it.
/// (Data-plane handlers keep the resume-on-caller behavior of
/// [`Pending::join`]: a storage-server bug should stay loud.)
pub fn serve_fail_stop(
    shard: u32,
    replica: u32,
    f: impl FnOnce() -> Result<Response>,
) -> Result<Response> {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(f))
        .unwrap_or_else(|_panic| Err(Error::ReplicaLost { shard, replica }))
}

/// The server side of the transport: anything that can serve envelopes.
/// Storage servers, baseline data nodes, and the metadata service each
/// implement this for the subset of requests they understand.
pub trait Handler: Send + Sync {
    fn serve(&self, req: &Request) -> Result<Response>;
}

/// A destination address: a shared handle to the serving component.
pub type Peer = Arc<dyn Handler>;

/// The in-flight result of a [`Transport::send`].
pub struct Pending {
    slot: Arc<Slot>,
}

/// A worker outcome: the served result, or the payload of a handler
/// panic (resumed on the joining caller so bugs stay fail-stop).
type Outcome = std::thread::Result<Result<Response>>;

struct Slot {
    result: Mutex<Option<Outcome>>,
    ready: Condvar,
}

impl Slot {
    fn new() -> Arc<Slot> {
        Arc::new(Slot {
            result: Mutex::new(None),
            ready: Condvar::new(),
        })
    }

    fn fill(&self, r: Outcome) {
        let mut g = self.result.lock().unwrap();
        *g = Some(r);
        self.ready.notify_all();
    }
}

impl Pending {
    /// Block until the response (or error) arrives.  A handler panic is
    /// resumed here, on the caller, exactly as a direct call would have
    /// panicked — the transport itself never converts bugs into `Err`s.
    /// (Metadata-plane handlers opt into fail-stop conversion via
    /// [`serve_fail_stop`], so a crashed quorum member degrades its
    /// group instead of taking the client thread with it.)
    pub fn join(self) -> Result<Response> {
        let mut g = self.slot.result.lock().unwrap();
        while g.is_none() {
            g = self.slot.ready.wait(g).unwrap();
        }
        match g.take().unwrap() {
            Ok(r) => r,
            Err(panic_payload) => std::panic::resume_unwind(panic_payload),
        }
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// The in-process transport: a worker pool plus the link model it
/// charges on behalf of callers.
pub struct Transport {
    link: LinkModel,
    /// `None` when `workers == 0`: inline serial execution.
    sender: Option<Mutex<mpsc::Sender<Job>>>,
    workers: u32,
    /// Envelopes ever sent — the read-path coalescing benchmarks count
    /// these (one `RetrieveMany` replaces many `RetrieveSlice`s).
    envelopes: std::sync::atomic::AtomicU64,
    /// Per-plane splits of `envelopes` (data / metadata / Paxos), so the
    /// write-path benches can report consensus traffic separately.
    /// Strictly additive: `envelopes` keeps its exact PR-3 semantics.
    data_envelopes: std::sync::atomic::AtomicU64,
    meta_envelopes: std::sync::atomic::AtomicU64,
    paxos_envelopes: std::sync::atomic::AtomicU64,
    /// `broadcast` calls ever issued — one scatter-gather, whatever its
    /// width.  Prepare batching collapses a 2PC commit's per-group
    /// scatters; this counter is what proves it.
    scatters: std::sync::atomic::AtomicU64,
    /// The optional turbulence (message-fault) layer.  `chaos_installed`
    /// is the fast path: with no turbulence the per-send overhead is one
    /// relaxed load and the wire behavior is byte-identical to a build
    /// without the feature.
    chaos: Mutex<Option<Arc<Turbulence>>>,
    chaos_installed: AtomicBool,
    /// When set, every envelope detours through a per-peer loopback
    /// socket pair (real framing, pool, failure mapping) instead of a
    /// direct method call — `WTF_SOCKET_TRANSPORT=1`, or the explicit
    /// [`Transport::socket_bridged`] constructor.  The bridge sits
    /// BEHIND the turbulence layer, so seeded fault schedules are
    /// byte-identical under both transports.
    bridge: Option<Arc<super::socket::SocketBridge>>,
}

impl fmt::Debug for Transport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Transport")
            .field("link", &self.link)
            .field("workers", &self.workers)
            .finish()
    }
}

impl Transport {
    /// Build a transport over `link` with `workers` pool threads.
    /// `workers == 0` means inline (serial) execution on the caller.
    pub fn new(link: LinkModel, workers: u32) -> Transport {
        let bridged = std::env::var_os("WTF_SOCKET_TRANSPORT").is_some_and(|v| v == "1");
        Transport::build(link, workers, bridged)
    }

    /// A transport whose envelopes travel over real loopback sockets —
    /// what `WTF_SOCKET_TRANSPORT=1` selects globally.
    pub fn socket_bridged(link: LinkModel, workers: u32) -> Transport {
        Transport::build(link, workers, true)
    }

    fn build(link: LinkModel, workers: u32, bridged: bool) -> Transport {
        let sender = if workers == 0 {
            None
        } else {
            let (tx, rx) = mpsc::channel::<Job>();
            let rx = Arc::new(Mutex::new(rx));
            for i in 0..workers {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("wtf-transport-{i}"))
                    .spawn(move || loop {
                        // Standard pool pattern: the receiver lock is held
                        // only while waiting for one job, never while
                        // running it.
                        let job = match rx.lock().unwrap().recv() {
                            Ok(j) => j,
                            Err(_) => return, // transport dropped
                        };
                        job();
                    })
                    .expect("spawn transport worker");
            }
            Some(Mutex::new(tx))
        };
        Transport {
            link,
            sender,
            workers,
            envelopes: std::sync::atomic::AtomicU64::new(0),
            data_envelopes: std::sync::atomic::AtomicU64::new(0),
            meta_envelopes: std::sync::atomic::AtomicU64::new(0),
            paxos_envelopes: std::sync::atomic::AtomicU64::new(0),
            scatters: std::sync::atomic::AtomicU64::new(0),
            chaos: Mutex::new(None),
            chaos_installed: AtomicBool::new(false),
            bridge: bridged.then(|| Arc::new(super::socket::SocketBridge::new())),
        }
    }

    /// True when envelopes travel through the loopback socket bridge.
    pub fn is_socket_bridged(&self) -> bool {
        self.bridge.is_some()
    }

    /// Install (or with `None` remove) the turbulence layer.  Chaos
    /// harnesses call this through `tests/support`; production never
    /// does, and with nothing installed the transport takes a one-load
    /// fast path past every turbulence hook.
    pub fn set_turbulence(&self, t: Option<Arc<Turbulence>>) {
        let installed = t.is_some();
        *self.chaos.lock().unwrap() = t;
        self.chaos_installed.store(installed, Ordering::Relaxed);
    }

    fn turbulence(&self) -> Option<Arc<Turbulence>> {
        if !self.chaos_installed.load(Ordering::Relaxed) {
            return None;
        }
        self.chaos.lock().unwrap().clone()
    }

    /// An instant-link transport (unit tests, real-perf mode).
    pub fn instant() -> Transport {
        Transport::new(LinkModel::instant(), 0)
    }

    pub fn link(&self) -> LinkModel {
        self.link
    }

    pub fn workers(&self) -> u32 {
        self.workers
    }

    /// Total envelopes ever sent through this transport.
    pub fn envelopes_sent(&self) -> u64 {
        self.envelopes.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Envelopes ever sent on one plane (data / metadata / Paxos).  The
    /// three planes partition [`Transport::envelopes_sent`] exactly.
    pub fn envelopes_sent_on(&self, plane: Plane) -> u64 {
        let c = match plane {
            Plane::Data => &self.data_envelopes,
            Plane::Meta => &self.meta_envelopes,
            Plane::Paxos => &self.paxos_envelopes,
        };
        c.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Scatter-gather batches ever issued via [`Transport::broadcast`]
    /// (a batch of any width counts once; single `send`s count zero).
    pub fn scatters_sent(&self) -> u64 {
        self.scatters.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Serve one envelope, charging the wire exactly once.  Runs on a
    /// worker thread (or inline when the pool is empty).
    fn execute(
        link: LinkModel,
        to: &Peer,
        req: &Request,
        bridge: Option<&super::socket::SocketBridge>,
    ) -> Result<Response> {
        let routed;
        let to = match bridge {
            Some(b) => {
                routed = b.route(to);
                &routed
            }
            None => to,
        };
        match req.wire_cost() {
            WireCost::Upload(bytes) => {
                link.charge(bytes);
                to.serve(req)
            }
            WireCost::Download => {
                let resp = to.serve(req)?;
                link.charge(resp.payload_len());
                Ok(resp)
            }
            WireCost::Free => to.serve(req),
        }
    }

    /// [`Transport::execute`] behind the turbulence layer.  With no
    /// turbulence installed this is exactly `execute`; otherwise the
    /// layer decides the envelope's fate:
    ///
    /// * `Drop` — the envelope never reaches the destination; the
    ///   caller's per-envelope wait expires into a typed
    ///   [`Error::Timeout`].  Because the error lands in this envelope's
    ///   own result slot, one dead destination degrades a scatter's
    ///   quorum without stalling the gather.
    /// * `Duplicate` — the destination serves the envelope twice (its
    ///   first ack "was lost"); handlers must be idempotent.
    /// * `AckLoss` — the destination serves the envelope (state may
    ///   move) but the caller still times out: outcome unknown.
    fn execute_faulted(
        link: LinkModel,
        to: &Peer,
        req: &Request,
        chaos: Option<&Turbulence>,
        bridge: Option<&super::socket::SocketBridge>,
    ) -> Result<Response> {
        let Some(chaos) = chaos else {
            return Self::execute(link, to, req, bridge);
        };
        match chaos.on_send(to, req) {
            Delivery::Deliver => Self::execute(link, to, req, bridge),
            Delivery::Duplicate => {
                let _first_ack_lost = Self::execute(link, to, req, bridge);
                Self::execute(link, to, req, bridge)
            }
            Delivery::Drop => Err(chaos.timeout(req.op_name())),
            Delivery::AckLoss => {
                let _ack_lost = Self::execute(link, to, req, bridge);
                Err(chaos.timeout(req.op_name()))
            }
        }
    }

    /// Asynchronously issue `req` to `to`; the wire time is paid on the
    /// worker, so the caller can overlap further sends with it.
    ///
    /// Wire-free envelopes (the metadata plane) execute inline on the
    /// caller: there is no transfer to overlap, and dispatching them to
    /// the pool would both add per-op overhead and let data-plane wire
    /// sleeps head-of-line-block metadata traffic.
    pub fn send(&self, to: Peer, req: Request) -> Pending {
        self.envelopes
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let plane_counter = match req.plane() {
            Plane::Data => &self.data_envelopes,
            Plane::Meta => &self.meta_envelopes,
            Plane::Paxos => &self.paxos_envelopes,
        };
        plane_counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let chaos = self.turbulence();
        let slot = Slot::new();
        let inline = self.sender.is_none() || matches!(req.wire_cost(), WireCost::Free);
        if inline {
            slot.fill(Ok(Self::execute_faulted(
                self.link,
                &to,
                &req,
                chaos.as_deref(),
                self.bridge.as_deref(),
            )));
            return Pending { slot };
        }
        let tx = self.sender.as_ref().expect("checked above");
        let job_slot = Arc::clone(&slot);
        let link = self.link;
        let bridge = self.bridge.clone();
        let job: Job = Box::new(move || {
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                Self::execute_faulted(link, &to, &req, chaos.as_deref(), bridge.as_deref())
            }));
            job_slot.fill(outcome);
        });
        if let Err(mpsc::SendError(job)) = tx.lock().unwrap().send(job) {
            // Channel closed (all workers gone): run inline.
            job();
        }
        Pending { slot }
    }

    /// Synchronous request/response.
    pub fn call(&self, to: Peer, req: Request) -> Result<Response> {
        self.send(to, req).join()
    }

    /// Scatter every `(destination, envelope)` pair onto the pool, then
    /// gather all results in input order.  The elapsed time is roughly
    /// the *maximum* single-envelope cost, not the sum; per-envelope
    /// failures are returned in place for caller-side failover.
    pub fn broadcast(&self, batch: Vec<(Peer, Request)>) -> Vec<Result<Response>> {
        self.scatters
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // Turbulence may reorder the scatter: envelopes are *issued* in
        // a seeded permutation (wire-free envelopes serve at issue time,
        // so issue order is delivery order), while results still gather
        // in the caller's batch order.
        let order = self
            .turbulence()
            .and_then(|c| c.scatter_order(&batch));
        let pending: Vec<Pending> = match order {
            None => batch
                .into_iter()
                .map(|(to, req)| self.send(to, req))
                .collect(),
            Some(order) => {
                let mut items: Vec<Option<(Peer, Request)>> =
                    batch.into_iter().map(Some).collect();
                let mut issued: Vec<Option<Pending>> =
                    (0..items.len()).map(|_| None).collect();
                for i in order {
                    let (to, req) = items[i].take().expect("permutation index");
                    issued[i] = Some(self.send(to, req));
                }
                issued
                    .into_iter()
                    .map(|p| p.expect("permutation covers every index"))
                    .collect()
            }
        };
        pending.into_iter().map(Pending::join).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::time::{Duration, Instant};

    /// A handler that echoes `len`-sized byte responses after recording
    /// the call.
    struct Echo {
        calls: AtomicU64,
    }

    impl Handler for Echo {
        fn serve(&self, req: &Request) -> Result<Response> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            match req {
                Request::ReadBlock { len, .. } => Ok(Response::Bytes(vec![7u8; *len as usize])),
                Request::AppendBlock { data, .. } => Ok(Response::BlockLen(data.len() as u64)),
                _ => Err(Error::Unsupported("echo".into())),
            }
        }
    }

    fn echo() -> Arc<Echo> {
        Arc::new(Echo {
            calls: AtomicU64::new(0),
        })
    }

    #[test]
    fn call_round_trips() {
        let t = Transport::new(LinkModel::instant(), 2);
        let e = echo();
        let resp = t
            .call(
                e.clone(),
                Request::ReadBlock {
                    block: 1,
                    offset: 0,
                    len: 4,
                },
            )
            .unwrap();
        assert_eq!(resp, Response::Bytes(vec![7u8; 4]));
        assert_eq!(e.calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn inline_mode_works_without_threads() {
        let t = Transport::new(LinkModel::instant(), 0);
        let e = echo();
        let resp = t
            .call(
                e.clone(),
                Request::AppendBlock {
                    block: 9,
                    data: Arc::from(&b"abc"[..]),
                },
            )
            .unwrap();
        assert_eq!(resp, Response::BlockLen(3));
    }

    #[test]
    fn broadcast_gathers_in_order_with_partial_failures() {
        let t = Transport::new(LinkModel::instant(), 4);
        let e = echo();
        let batch: Vec<(Peer, Request)> = vec![
            (
                e.clone() as Peer,
                Request::ReadBlock {
                    block: 0,
                    offset: 0,
                    len: 1,
                },
            ),
            (
                e.clone() as Peer,
                Request::MetaGet {
                    key: Key::sys("nope"),
                }, // unsupported -> Err
            ),
            (
                e.clone() as Peer,
                Request::ReadBlock {
                    block: 0,
                    offset: 0,
                    len: 3,
                },
            ),
        ];
        let results = t.broadcast(batch);
        assert_eq!(results.len(), 3);
        assert_eq!(*results[0].as_ref().unwrap(), Response::Bytes(vec![7u8; 1]));
        assert!(results[1].is_err());
        assert_eq!(*results[2].as_ref().unwrap(), Response::Bytes(vec![7u8; 3]));
    }

    #[test]
    fn envelope_counter_counts_every_send() {
        let t = Transport::new(LinkModel::instant(), 2);
        let e = echo();
        assert_eq!(t.envelopes_sent(), 0);
        for i in 0..3 {
            let _ = t.call(
                e.clone(),
                Request::ReadBlock {
                    block: i,
                    offset: 0,
                    len: 1,
                },
            );
        }
        assert_eq!(t.envelopes_sent(), 3);
    }

    #[test]
    fn per_plane_counters_partition_the_total() {
        let t = Transport::new(LinkModel::instant(), 0);
        let e = echo();
        // One data-plane envelope...
        let _ = t.call(
            e.clone(),
            Request::ReadBlock {
                block: 0,
                offset: 0,
                len: 1,
            },
        );
        // ...one metadata envelope (unsupported by Echo, still counted)...
        let _ = t.call(
            e.clone(),
            Request::MetaGet {
                key: Key::sys("k"),
            },
        );
        // ...and two Paxos-plane envelopes in one scatter.
        let _ = t.broadcast(vec![
            (e.clone() as Peer, Request::PaxosStatus { shard: 0 }),
            (e.clone() as Peer, Request::PaxosStatus { shard: 1 }),
        ]);
        assert_eq!(t.envelopes_sent(), 4);
        assert_eq!(t.envelopes_sent_on(Plane::Data), 1);
        assert_eq!(t.envelopes_sent_on(Plane::Meta), 1);
        assert_eq!(t.envelopes_sent_on(Plane::Paxos), 2);
        assert_eq!(
            t.envelopes_sent_on(Plane::Data)
                + t.envelopes_sent_on(Plane::Meta)
                + t.envelopes_sent_on(Plane::Paxos),
            t.envelopes_sent(),
            "planes partition the total exactly"
        );
        assert_eq!(t.scatters_sent(), 1, "one broadcast = one scatter");
    }

    #[test]
    fn bytes_many_payload_sums_served_items() {
        let r = Response::BytesMany(vec![Some(vec![0u8; 10]), None, Some(vec![0u8; 5])]);
        assert_eq!(r.payload_len(), 15);
        assert_eq!(r.clone().into_bytes_many().unwrap().len(), 3);
        assert!(Response::Learned.into_bytes_many().is_err());
    }

    /// A handler that sleeps, standing in for wire time, to prove the
    /// scatter actually overlaps.
    struct Slow;

    impl Handler for Slow {
        fn serve(&self, _req: &Request) -> Result<Response> {
            std::thread::sleep(Duration::from_millis(50));
            Ok(Response::BlockLen(0))
        }
    }

    #[test]
    fn broadcast_overlaps_wire_time() {
        let t = Transport::new(LinkModel::instant(), 4);
        let s: Peer = Arc::new(Slow);
        let batch: Vec<(Peer, Request)> = (0..4)
            .map(|i| {
                (
                    s.clone(),
                    Request::ReadBlock {
                        block: i,
                        offset: 0,
                        len: 0,
                    },
                )
            })
            .collect();
        let t0 = Instant::now();
        let results = t.broadcast(batch);
        let elapsed = t0.elapsed();
        assert!(results.iter().all(|r| r.is_ok()));
        // 4 x 50 ms serial would be >= 200 ms; overlapped is ~50 ms.  The
        // bound leaves >100 ms of slack for loaded CI machines.
        assert!(
            elapsed < Duration::from_millis(160),
            "broadcast did not overlap: {elapsed:?}"
        );
    }

    #[test]
    fn turbulence_cut_fails_one_destination_without_stalling_the_scatter() {
        use crate::net::chaos::{CutMode, Turbulence};
        let t = Transport::new(LinkModel::instant(), 0);
        let a = echo();
        let b = echo();
        let chaos = Turbulence::new(1, crate::coordinator::lease::LeaseClock::manual());
        let cut: Peer = b.clone();
        chaos.cut(&cut, CutMode::Both);
        t.set_turbulence(Some(chaos.clone()));
        let read = |block| Request::ReadBlock {
            block,
            offset: 0,
            len: 1,
        };
        let results = t.broadcast(vec![
            (a.clone() as Peer, read(0)),
            (cut.clone(), read(1)),
            (a.clone() as Peer, read(2)),
        ]);
        assert!(results[0].is_ok());
        assert!(
            matches!(results[1], Err(Error::Timeout { .. })),
            "cut destination fails with a typed timeout"
        );
        assert!(results[2].is_ok(), "the rest of the scatter is unharmed");
        assert_eq!(b.calls.load(Ordering::Relaxed), 0, "symmetric cut never serves");
        assert_eq!(t.envelopes_sent(), 3, "dropped envelopes still count as sends");
        chaos.heal_cut(&cut);
        assert!(t.call(cut, read(3)).is_ok(), "healed link delivers again");
    }

    #[test]
    fn turbulence_duplicate_double_serves_and_ack_loss_serves_but_errs() {
        use crate::net::chaos::{CutMode, Turbulence, TurbulenceRule};
        let t = Transport::new(LinkModel::instant(), 0);
        let e = echo();
        let chaos = Turbulence::new(2, crate::coordinator::lease::LeaseClock::manual());
        chaos.add_rule(TurbulenceRule {
            dup: 1024, // always
            ..Default::default()
        });
        t.set_turbulence(Some(chaos.clone()));
        let read = Request::ReadBlock {
            block: 0,
            offset: 0,
            len: 1,
        };
        assert!(t.call(e.clone(), read.clone()).is_ok());
        assert_eq!(
            e.calls.load(Ordering::Relaxed),
            2,
            "duplicate delivery serves the envelope twice"
        );
        // Asymmetric partition: the request lands, the ack does not.
        let victim: Peer = e.clone();
        chaos.cut(&victim, CutMode::AckLoss);
        assert!(matches!(
            t.call(victim, read),
            Err(Error::Timeout { .. })
        ));
        assert_eq!(
            e.calls.load(Ordering::Relaxed),
            3,
            "ack-loss still changed server state"
        );
        assert!(chaos.faults_injected() >= 2);
    }

    #[test]
    fn turbulence_schedules_replay_from_the_seed() {
        use crate::net::chaos::{Turbulence, TurbulenceRule};
        let run = |seed: u64| {
            let t = Transport::new(LinkModel::instant(), 0);
            let e = echo();
            let chaos = Turbulence::new(seed, crate::coordinator::lease::LeaseClock::manual());
            chaos.add_rule(TurbulenceRule {
                drop: 512,
                dup: 128,
                ..Default::default()
            });
            t.set_turbulence(Some(chaos.clone()));
            let oks: Vec<bool> = (0..64)
                .map(|i| {
                    t.call(
                        e.clone(),
                        Request::ReadBlock {
                            block: i,
                            offset: 0,
                            len: 1,
                        },
                    )
                    .is_ok()
                })
                .collect();
            (chaos.dropped(), chaos.duplicated(), oks)
        };
        assert_eq!(run(42), run(42), "same seed, same schedule");
        assert_ne!(run(42).2, run(43).2, "different seed, different schedule");
    }

    #[test]
    fn turbulence_uninstall_restores_clean_delivery() {
        use crate::net::chaos::{Turbulence, TurbulenceRule};
        let t = Transport::new(LinkModel::instant(), 0);
        let e = echo();
        let chaos = Turbulence::new(3, crate::coordinator::lease::LeaseClock::manual());
        chaos.add_rule(TurbulenceRule {
            drop: 1024, // always
            ..Default::default()
        });
        t.set_turbulence(Some(chaos));
        let read = Request::ReadBlock {
            block: 0,
            offset: 0,
            len: 1,
        };
        assert!(t.call(e.clone(), read.clone()).is_err());
        t.set_turbulence(None);
        assert!(t.call(e.clone(), read).is_ok());
        assert_eq!(e.calls.load(Ordering::Relaxed), 1, "only the clean send served");
    }

    #[test]
    fn turbulence_reorders_scatter_issue_order_but_not_gather_order() {
        use crate::net::chaos::{Turbulence, TurbulenceRule};
        struct Rec {
            served: Mutex<Vec<u64>>,
        }
        impl Handler for Rec {
            fn serve(&self, req: &Request) -> Result<Response> {
                if let Request::ReadBlock { block, .. } = req {
                    self.served.lock().unwrap().push(*block);
                }
                Ok(Response::Bytes(Vec::new()))
            }
        }
        let identity: Vec<u64> = (0..8).collect();
        let mut saw_permuted = false;
        for seed in 0..4u64 {
            let rec = Arc::new(Rec {
                served: Mutex::new(Vec::new()),
            });
            let t = Transport::new(LinkModel::instant(), 0);
            let chaos = Turbulence::new(seed, crate::coordinator::lease::LeaseClock::manual());
            chaos.add_rule(TurbulenceRule {
                reorder: 1024, // always
                ..Default::default()
            });
            t.set_turbulence(Some(chaos.clone()));
            let batch: Vec<(Peer, Request)> = (0..8)
                .map(|i| {
                    (
                        rec.clone() as Peer,
                        Request::ReadBlock {
                            block: i,
                            offset: 0,
                            len: 0,
                        },
                    )
                })
                .collect();
            let results = t.broadcast(batch);
            assert!(results.iter().all(|r| r.is_ok()), "gather keeps every result");
            assert_eq!(chaos.reordered(), 1);
            let served = rec.served.lock().unwrap().clone();
            let mut sorted = served.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, identity, "every envelope served exactly once");
            if served != identity {
                saw_permuted = true;
            }
        }
        assert!(saw_permuted, "no seed permuted an 8-wide scatter");
    }

    #[test]
    fn upload_cost_is_charged_once_per_envelope() {
        // A measurable link: 20 ms per upload, infinite bandwidth.
        let link = LinkModel {
            half_rtt: Duration::from_millis(20),
            bandwidth: None,
        };
        let t = Transport::new(link, 4);
        let e = echo();
        let batch: Vec<(Peer, Request)> = (0..4)
            .map(|_| {
                (
                    e.clone() as Peer,
                    Request::AppendBlock {
                        block: 0,
                        data: Arc::from(&b"x"[..]),
                    },
                )
            })
            .collect();
        let t0 = Instant::now();
        t.broadcast(batch);
        let elapsed = t0.elapsed();
        // Parallel: ~20 ms total; serial would be >= 80 ms.  Generous
        // slack on both sides for noisy CI schedulers.
        assert!(elapsed >= Duration::from_millis(18), "{elapsed:?}");
        assert!(elapsed < Duration::from_millis(65), "{elapsed:?}");
    }
}
