//! In-process "network": latency/bandwidth injection between components.
//!
//! The functional deployment runs every server in one process, so RPC is a
//! method call.  To keep the *shape* of a distributed deployment (and to
//! let real-mode benchmarks model the paper's GbE testbed), every
//! cross-component call site threads through a [`LinkModel`] that can
//! charge latency and bandwidth with thread sleeps.  Unit tests use
//! [`LinkModel::instant`].

use std::time::Duration;

/// Latency + bandwidth model for one logical link (client ↔ server).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkModel {
    /// One-way propagation delay charged per message.
    pub half_rtt: Duration,
    /// Payload bandwidth in bytes/second; `None` = infinite.
    pub bandwidth: Option<u64>,
}

impl LinkModel {
    /// No simulated cost at all (unit tests, real-perf mode).
    pub const fn instant() -> Self {
        LinkModel {
            half_rtt: Duration::ZERO,
            bandwidth: None,
        }
    }

    /// The paper's testbed: gigabit ethernet through one ToR switch.
    /// ~0.1 ms one-way, 125 MB/s payload bandwidth.
    pub const fn gigabit() -> Self {
        LinkModel {
            half_rtt: Duration::from_micros(100),
            bandwidth: Some(125_000_000),
        }
    }

    /// Time to move `bytes` across this link, one way.
    pub fn transfer_time(&self, bytes: u64) -> Duration {
        let bw = match self.bandwidth {
            Some(bw) if bw > 0 => {
                Duration::from_nanos((bytes.saturating_mul(1_000_000_000) / bw).max(0))
            }
            _ => Duration::ZERO,
        };
        self.half_rtt + bw
    }

    /// Sleep for the cost of sending `bytes` over this link.  A no-op for
    /// [`LinkModel::instant`] so unit tests never yield.
    pub fn charge(&self, bytes: u64) {
        let t = self.transfer_time(bytes);
        if t > Duration::ZERO {
            std::thread::sleep(t);
        }
    }
}

impl Default for LinkModel {
    fn default() -> Self {
        LinkModel::instant()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instant_charges_nothing() {
        let l = LinkModel::instant();
        assert_eq!(l.transfer_time(1 << 30), Duration::ZERO);
    }

    #[test]
    fn gigabit_transfer_time() {
        let l = LinkModel::gigabit();
        // 125 MB at 125 MB/s = 1 s + 0.1 ms propagation.
        let t = l.transfer_time(125_000_000);
        assert!(t >= Duration::from_secs(1));
        assert!(t < Duration::from_millis(1002));
    }

    #[test]
    fn charge_is_noop_when_instant() {
        LinkModel::instant().charge(u64::MAX / 2);
    }
}
