//! In-process "network": the [`Transport`] RPC layer plus the
//! latency/bandwidth [`LinkModel`] it charges.
//!
//! The functional deployment runs every server in one process, so an RPC
//! bottoms out in a method call — but every cross-component call still
//! travels as a [`transport::Request`] envelope through a [`Transport`],
//! which keeps the *shape* of a distributed deployment and lets
//! real-mode benchmarks model the paper's GbE testbed:
//!
//! * [`LinkModel`] prices one logical link (client ↔ server): one-way
//!   propagation delay plus payload bandwidth, charged with thread
//!   sleeps.  Unit tests use [`LinkModel::instant`], which never sleeps.
//! * [`Transport`] executes envelopes on a worker pool and charges the
//!   link *on the worker*, so a scatter-gather
//!   ([`Transport::broadcast`]) of `r` replica uploads costs ~one wire
//!   time instead of `r` — the §2.1 concurrency the slice-first write
//!   protocol permits.  Storage servers, hdfs-lite data nodes, and the
//!   metadata service all serve requests through
//!   [`transport::Handler`] implementations.

pub mod chaos;
pub mod codec;
pub mod socket;
pub mod transport;

pub use chaos::{CutMode, Turbulence, TurbulenceRule};
pub use socket::{SocketBridge, SocketPeer, SocketServer};
pub use transport::{serve_fail_stop, Handler, Peer, Pending, Plane, Request, Response, Transport};

use std::time::Duration;

/// Latency + bandwidth model for one logical link (client ↔ server).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkModel {
    /// One-way propagation delay charged per message.
    pub half_rtt: Duration,
    /// Payload bandwidth in bytes/second; `None` = infinite.
    pub bandwidth: Option<u64>,
}

impl LinkModel {
    /// No simulated cost at all (unit tests, real-perf mode).
    pub const fn instant() -> Self {
        LinkModel {
            half_rtt: Duration::ZERO,
            bandwidth: None,
        }
    }

    /// The paper's testbed: gigabit ethernet through one ToR switch.
    /// ~0.1 ms one-way, 125 MB/s payload bandwidth.
    pub const fn gigabit() -> Self {
        LinkModel {
            half_rtt: Duration::from_micros(100),
            bandwidth: Some(125_000_000),
        }
    }

    /// Time to move `bytes` across this link, one way.
    ///
    /// The nanosecond arithmetic runs in u128: `bytes * 1e9` overflows
    /// u64 for payloads beyond ~18 GB, and the previous `saturating_mul`
    /// silently under-charged bandwidth for them.
    pub fn transfer_time(&self, bytes: u64) -> Duration {
        let bw = match self.bandwidth {
            Some(bw) if bw > 0 => {
                let nanos = (bytes as u128) * 1_000_000_000u128 / bw as u128;
                Duration::from_nanos(u64::try_from(nanos).unwrap_or(u64::MAX))
            }
            _ => Duration::ZERO,
        };
        self.half_rtt.saturating_add(bw)
    }

    /// Sleep for the cost of sending `bytes` over this link.  A no-op for
    /// [`LinkModel::instant`] so unit tests never yield.
    pub fn charge(&self, bytes: u64) {
        let t = self.transfer_time(bytes);
        if t > Duration::ZERO {
            std::thread::sleep(t);
        }
    }
}

impl Default for LinkModel {
    fn default() -> Self {
        LinkModel::instant()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instant_charges_nothing() {
        let l = LinkModel::instant();
        assert_eq!(l.transfer_time(1 << 30), Duration::ZERO);
    }

    #[test]
    fn gigabit_transfer_time() {
        let l = LinkModel::gigabit();
        // 125 MB at 125 MB/s = 1 s + 0.1 ms propagation.
        let t = l.transfer_time(125_000_000);
        assert!(t >= Duration::from_secs(1));
        assert!(t < Duration::from_millis(1002));
    }

    #[test]
    fn charge_is_noop_when_instant() {
        LinkModel::instant().charge(u64::MAX / 2);
    }

    #[test]
    fn transfer_time_survives_huge_payloads() {
        // Regression: 32 GB at 125 MB/s is 256 s.  The old u64 nanosecond
        // product saturated at ~18.4 GB and reported ~147 s instead.
        let l = LinkModel::gigabit();
        let t = l.transfer_time(32_000_000_000);
        assert!(t >= Duration::from_secs(255), "{t:?}");
        assert!(t <= Duration::from_secs(257), "{t:?}");
        // Monotone beyond the old saturation point.
        assert!(l.transfer_time(40_000_000_000) > t);
    }
}
