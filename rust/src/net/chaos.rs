//! Deterministic message-fault injection ("turbulence") for the
//! in-process [`Transport`](super::Transport).
//!
//! The protocol stack above the transport — Paxos groups, 2PC, leases —
//! must survive a network that drops, delays, duplicates, reorders, and
//! partitions messages.  The in-process transport delivers every
//! envelope synchronously and exactly once, so this layer *synthesizes*
//! each network fault at the send site:
//!
//! * **drop / symmetric partition** — the envelope never reaches the
//!   destination; the caller gets a typed [`Error::Timeout`] in place
//!   (its per-envelope wait expired), degrading the quorum exactly like
//!   an unreachable peer.  Because results come back per envelope, one
//!   cut destination never stalls the rest of a scatter.
//! * **asymmetric partition (ack loss)** — the request IS served (the
//!   replica's state may change) but the acknowledgment is lost: the
//!   caller sees [`Error::Timeout`] while the server moved.  This is the
//!   canonical indeterminate-outcome generator.
//! * **delay** — the shared [`LeaseClock`] jumps forward before the
//!   envelope is served, modeling a message that arrived late — possibly
//!   after the lease window it was trying to refresh.
//! * **duplicate** — the envelope is served twice back-to-back; the
//!   second response is returned (the first ack "was lost on the wire").
//!   Handlers must be idempotent for this to be invisible.
//! * **reorder** — a scatter's envelopes are issued in a seeded
//!   permutation instead of batch order (results still gather in the
//!   caller's order), so replicas observe learn/accept traffic out of
//!   order.
//!
//! Everything is driven by a seeded [`Rng`], so a schedule replays
//! bit-for-bit from its seed (the chaos CI matrix derives seeds from
//! `WTF_TEST_SEED` and failures print them).  With no [`Turbulence`]
//! installed the transport's behavior is byte-identical to the
//! fault-free build — the hook is one relaxed atomic load.

use super::transport::{Peer, Plane, Request};
use crate::coordinator::lease::LeaseClock;
use crate::error::Error;
use crate::util::Rng;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How a scripted partition treats traffic to a cut destination.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CutMode {
    /// Symmetric: the request never arrives; the destination's state is
    /// untouched and the caller times out.
    Both,
    /// Asymmetric: the request arrives and is served (state may move),
    /// but the acknowledgment is lost — the caller times out with the
    /// outcome genuinely unknown.
    AckLoss,
}

/// One per-plane probabilistic fault rule.  Probabilities are
/// per-1024 (integer dice keep schedules exactly reproducible across
/// platforms); a field of 0 disables that fault.  `plane`/`shard` of
/// `None` match every envelope.
#[derive(Clone, Copy, Debug, Default)]
pub struct TurbulenceRule {
    /// Restrict to one plane (`None` = all planes).
    pub plane: Option<Plane>,
    /// Restrict to one shard's traffic (`None` = all; envelopes that
    /// carry no shard — the client-facing metadata/data planes — only
    /// match shard-less rules).
    pub shard: Option<u32>,
    /// Chance (per 1024) the envelope is dropped outright.
    pub drop: u32,
    /// Chance (per 1024) the envelope is served twice (duplicate
    /// delivery; the handler must be idempotent).
    pub dup: u32,
    /// Chance (per 1024) the envelope is delayed: the shared lease
    /// clock advances by `delay_ms` before the envelope is served.
    pub delay: u32,
    /// Clock advance applied when `delay` fires.  Bounded by the rule
    /// author; choose `> lease_ms` to push renewals past their window.
    pub delay_ms: u64,
    /// Chance (per 1024), evaluated once per scatter containing a
    /// matching envelope, that the whole scatter is issued in a seeded
    /// permutation (reordered delivery).
    pub reorder: u32,
}

impl TurbulenceRule {
    fn matches(&self, req: &Request) -> bool {
        if let Some(p) = self.plane {
            if req.plane() != p {
                return false;
            }
        }
        if let Some(s) = self.shard {
            if req.shard() != Some(s) {
                return false;
            }
        }
        true
    }
}

/// What the turbulence layer decided for one envelope.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Delivery {
    Deliver,
    Duplicate,
    Drop,
    AckLoss,
}

/// The seeded turbulence layer.  Install on a transport with
/// [`Transport::set_turbulence`](super::Transport::set_turbulence);
/// script partitions with [`Turbulence::cut`]/[`Turbulence::heal_cut`]
/// and background noise with [`Turbulence::add_rule`].
pub struct Turbulence {
    rng: Mutex<Rng>,
    rules: Mutex<Vec<TurbulenceRule>>,
    /// Cut destinations, keyed by handler identity (thin pointer).
    cuts: Mutex<HashMap<usize, CutMode>>,
    /// The cluster's shared clock: delays advance it so "this message
    /// arrived late" and "the lease window passed" are the same fact.
    clock: LeaseClock,
    /// Synthesized per-envelope wait behind every injected timeout.
    timeout_ms: u64,
    dropped: AtomicU64,
    duplicated: AtomicU64,
    delayed: AtomicU64,
    reordered: AtomicU64,
    acks_lost: AtomicU64,
}

fn peer_key(peer: &Peer) -> usize {
    Arc::as_ptr(peer) as *const () as usize
}

impl Turbulence {
    /// A turbulence layer deterministic in `seed`, advancing `clock`
    /// (the cluster's lease clock) on delay faults.
    pub fn new(seed: u64, clock: LeaseClock) -> Arc<Turbulence> {
        Arc::new(Turbulence {
            rng: Mutex::new(Rng::new(seed)),
            rules: Mutex::new(Vec::new()),
            cuts: Mutex::new(HashMap::new()),
            clock,
            timeout_ms: 5,
            dropped: AtomicU64::new(0),
            duplicated: AtomicU64::new(0),
            delayed: AtomicU64::new(0),
            reordered: AtomicU64::new(0),
            acks_lost: AtomicU64::new(0),
        })
    }

    /// Add a probabilistic fault rule (rules are tried in insertion
    /// order; the first matching rule rolls the dice for its envelope).
    pub fn add_rule(&self, rule: TurbulenceRule) {
        self.rules.lock().unwrap().push(rule);
    }

    /// Remove every probabilistic rule (scripted cuts stay).
    pub fn clear_rules(&self) {
        self.rules.lock().unwrap().clear();
    }

    /// Cut the link to `peer`: every envelope addressed to it fails
    /// with [`Error::Timeout`] until [`Turbulence::heal_cut`].  With
    /// [`CutMode::AckLoss`] the envelope is still served first.
    pub fn cut(&self, peer: &Peer, mode: CutMode) {
        self.cuts.lock().unwrap().insert(peer_key(peer), mode);
    }

    /// Restore the link to `peer`.
    pub fn heal_cut(&self, peer: &Peer) {
        self.cuts.lock().unwrap().remove(&peer_key(peer));
    }

    /// Restore every cut link.
    pub fn heal_all_cuts(&self) {
        self.cuts.lock().unwrap().clear();
    }

    /// The typed error behind every synthesized drop/ack-loss.
    pub(crate) fn timeout(&self, op: &'static str) -> Error {
        Error::Timeout {
            op,
            elapsed: Duration::from_millis(self.timeout_ms),
        }
    }

    /// Decide the fate of one envelope.  Delay faults take effect here
    /// (the clock advances), independent of the delivery verdict.
    pub(crate) fn on_send(&self, to: &Peer, req: &Request) -> Delivery {
        if let Some(mode) = self.cuts.lock().unwrap().get(&peer_key(to)) {
            return match mode {
                CutMode::Both => {
                    self.dropped.fetch_add(1, Ordering::Relaxed);
                    Delivery::Drop
                }
                CutMode::AckLoss => {
                    self.acks_lost.fetch_add(1, Ordering::Relaxed);
                    Delivery::AckLoss
                }
            };
        }
        let rule = {
            let rules = self.rules.lock().unwrap();
            match rules.iter().find(|r| r.matches(req)) {
                Some(r) => *r,
                None => return Delivery::Deliver,
            }
        };
        let mut rng = self.rng.lock().unwrap();
        if rule.delay > 0 && rng.next_below(1024) < u64::from(rule.delay) {
            // The message is in flight while the world moves on.
            self.delayed.fetch_add(1, Ordering::Relaxed);
            self.clock.advance(rule.delay_ms);
        }
        if rule.drop > 0 && rng.next_below(1024) < u64::from(rule.drop) {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return Delivery::Drop;
        }
        if rule.dup > 0 && rng.next_below(1024) < u64::from(rule.dup) {
            self.duplicated.fetch_add(1, Ordering::Relaxed);
            return Delivery::Duplicate;
        }
        Delivery::Deliver
    }

    /// Maybe reorder one scatter: if any envelope matches a rule with
    /// `reorder > 0` and the dice fire, return the seeded permutation
    /// the scatter must be issued in.  `None` means batch order.
    pub(crate) fn scatter_order(&self, batch: &[(Peer, Request)]) -> Option<Vec<usize>> {
        if batch.len() < 2 {
            return None;
        }
        let chance = {
            let rules = self.rules.lock().unwrap();
            batch
                .iter()
                .filter_map(|(_, req)| {
                    rules
                        .iter()
                        .find(|r| r.matches(req))
                        .map(|r| r.reorder)
                })
                .max()
                .unwrap_or(0)
        };
        if chance == 0 {
            return None;
        }
        let mut rng = self.rng.lock().unwrap();
        if rng.next_below(1024) >= u64::from(chance) {
            return None;
        }
        self.reordered.fetch_add(1, Ordering::Relaxed);
        let mut order: Vec<usize> = (0..batch.len()).collect();
        rng.shuffle(&mut order);
        Some(order)
    }

    /// Envelopes dropped (including symmetric-cut traffic).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Envelopes served twice.
    pub fn duplicated(&self) -> u64 {
        self.duplicated.load(Ordering::Relaxed)
    }

    /// Envelopes delayed (clock advanced before serving).
    pub fn delayed(&self) -> u64 {
        self.delayed.load(Ordering::Relaxed)
    }

    /// Scatters issued in a permuted order.
    pub fn reordered(&self) -> u64 {
        self.reordered.load(Ordering::Relaxed)
    }

    /// Envelopes served whose acknowledgment was lost.
    pub fn acks_lost(&self) -> u64 {
        self.acks_lost.load(Ordering::Relaxed)
    }

    /// Total faults injected so far (a schedule that injected nothing
    /// proved nothing — harnesses assert this moved).
    pub fn faults_injected(&self) -> u64 {
        self.dropped()
            + self.duplicated()
            + self.delayed()
            + self.reordered()
            + self.acks_lost()
    }
}

impl std::fmt::Debug for Turbulence {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Turbulence")
            .field("rules", &self.rules.lock().unwrap().len())
            .field("cuts", &self.cuts.lock().unwrap().len())
            .field("dropped", &self.dropped())
            .field("duplicated", &self.duplicated())
            .field("delayed", &self.delayed())
            .field("reordered", &self.reordered())
            .field("acks_lost", &self.acks_lost())
            .finish()
    }
}
