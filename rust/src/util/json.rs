//! A minimal JSON parser — just enough to read `artifacts/manifest.json`
//! (objects, arrays, strings, integers, floats, bools, null).  Offline
//! build: serde_json is unavailable.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Parse error with byte position.
#[derive(Debug, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: &'static str,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let b = input.as_bytes();
    let mut p = Parser { b, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &'static str) -> JsonError {
        JsonError { pos: self.i, msg }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err("unexpected character"))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("eof in string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogates unsupported (manifest is ASCII).
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Copy one UTF-8 scalar.
                    let start = self.i;
                    let rest = &self.b[start..];
                    let text = std::str::from_utf8(rest).map_err(|_| self.err("bad utf8"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError {
                pos: start,
                msg: "bad number",
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
          "sort_n1024": {
            "entry": "plan_sort",
            "file": "sort_n1024.hlo.txt",
            "n": 1024,
            "params": [{"name": "keys", "shape": [1024], "dtype": "i32"}]
          }
        }"#;
        let j = parse(doc).unwrap();
        let e = j.get("sort_n1024").unwrap();
        assert_eq!(e.get("entry").unwrap().as_str(), Some("plan_sort"));
        assert_eq!(e.get("n").unwrap().as_u64(), Some(1024));
        let params = e.get("params").unwrap().as_arr().unwrap();
        assert_eq!(
            params[0].get("shape").unwrap().as_arr().unwrap()[0].as_u64(),
            Some(1024)
        );
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(
            parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("07x").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{} trailing").is_err());
    }

    #[test]
    fn nested_structures() {
        let j = parse(r#"[{"a": [1, 2, {"b": false}]}, null]"#).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(
            arr[0].get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap(),
            &Json::Bool(false)
        );
    }
}
