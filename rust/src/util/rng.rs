//! Small, fast, seedable RNG (xoshiro256**) for workload generation —
//! deterministic across runs, which the benchmark harness relies on.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 seed yields a well-mixed state.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`; `bound` must be > 0.
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        // Lemire's multiply-shift rejection-free approximation is fine
        // for workload generation.
        ((u128::from(self.next_u64()) * u128::from(bound)) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi);
        lo + self.next_below(hi - lo)
    }

    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Bounded exponential backoff with full jitter for client RPC retry
/// loops: attempt `n` sleeps uniformly in `[0, base * 2^min(n-1, 6))`
/// (the 64x cap bounds the worst pause).  A ZERO `base` disables
/// backoff entirely — the retry is immediate, byte-identical to the
/// pre-backoff loops.  Jitter derives from a process-global counter
/// through the seeded [`Rng`], so concurrent retry storms decorrelate
/// without sharing an RNG, and two identical single-threaded runs pick
/// identical pauses.
pub fn backoff_jitter(base: std::time::Duration, attempt: u32) -> std::time::Duration {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SALT: AtomicU64 = AtomicU64::new(0);
    if base.is_zero() || attempt == 0 {
        return std::time::Duration::ZERO;
    }
    let window = base.saturating_mul(1 << attempt.saturating_sub(1).min(6));
    let salt = SALT.fetch_add(1, Ordering::Relaxed);
    let mut rng = Rng::new(salt ^ (u64::from(attempt) << 56));
    let nanos = u64::try_from(window.as_nanos()).unwrap_or(u64::MAX);
    std::time::Duration::from_nanos(rng.next_below(nanos.max(1)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn bounds_respected() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.range(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut r = Rng::new(7);
        let mut buckets = [0u32; 10];
        for _ in 0..100_000 {
            buckets[r.next_below(10) as usize] += 1;
        }
        for b in buckets {
            assert!((8_000..12_000).contains(&b), "bucket count {b}");
        }
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(3);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn backoff_window_is_bounded_and_zero_base_is_free() {
        use std::time::Duration;
        assert_eq!(backoff_jitter(Duration::ZERO, 5), Duration::ZERO);
        assert_eq!(backoff_jitter(Duration::from_millis(1), 0), Duration::ZERO);
        for attempt in 1..20u32 {
            let d = backoff_jitter(Duration::from_millis(1), attempt);
            // Window caps at base * 64 no matter how high the attempt.
            assert!(d < Duration::from_millis(64), "attempt {attempt}: {d:?}");
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
