//! In-tree utilities that replace unavailable third-party crates: this
//! repository builds fully offline (see Cargo.toml), so temp dirs, RNG,
//! and JSON parsing are implemented here.

pub mod json;
pub mod rng;
pub mod tempdir;

pub use rng::{backoff_jitter, Rng};
pub use tempdir::TempDir;

/// Wall-clock "now" in seconds (`SystemTime`, NOT monotonic) for inode
/// mtime stamping and log/bench rows only.  Correctness-critical timing
/// — leases, coordinator claims, GC deadlines — must never use this:
/// those paths use `coordinator::lease::LeaseClock` / `Instant`, which
/// cannot jump backwards under NTP step or clock skew.
pub fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}
