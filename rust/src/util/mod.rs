//! In-tree utilities that replace unavailable third-party crates: this
//! repository builds fully offline (see Cargo.toml), so temp dirs, RNG,
//! and JSON parsing are implemented here.

pub mod json;
pub mod rng;
pub mod tempdir;

pub use rng::{backoff_jitter, Rng};
pub use tempdir::TempDir;

/// Monotonic "now" in seconds for mtime stamping (coarse is fine: the
/// paper's inode mtimes are advisory).
pub fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}
