//! Minimal RAII temporary directories (stand-in for the `tempfile` crate).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A directory under the system temp root, removed on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create `TMPDIR/<prefix>-<pid>-<n>`.
    pub fn new(prefix: &str) -> std::io::Result<TempDir> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "{prefix}-{}-{}-{n}",
            std::process::id(),
            unique_suffix()
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

fn unique_suffix() -> u64 {
    use std::time::{SystemTime, UNIX_EPOCH};
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.subsec_nanos() as u64)
        .unwrap_or(0)
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_removes() {
        let p;
        {
            let t = TempDir::new("wtf-test").unwrap();
            p = t.path().to_path_buf();
            assert!(p.is_dir());
            std::fs::write(p.join("f"), b"x").unwrap();
        }
        assert!(!p.exists());
    }

    #[test]
    fn distinct_dirs() {
        let a = TempDir::new("wtf-test").unwrap();
        let b = TempDir::new("wtf-test").unwrap();
        assert_ne!(a.path(), b.path());
    }
}
