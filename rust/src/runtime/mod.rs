//! PJRT execution of the AOT-compiled JAX/Pallas kernels (L1/L2).
//!
//! `make artifacts` lowers the L2 entry points (`python/compile/model.py`,
//! which call the L1 Pallas kernels) to **HLO text** — the only
//! interchange format the bundled xla_extension 0.5.1 accepts from
//! jax ≥ 0.5 — plus a `manifest.json` describing every variant.  This
//! module loads those artifacts once (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → compile) and exposes typed entry
//! points; Python never runs at request time.
//!
//! [`SortCompute`] abstracts the two kernels the §4.1 sort application
//! needs (bucket partitioning, permutation sort) so unit tests can run
//! against the pure-rust [`NativeCompute`] oracle while examples and
//! benches use the real [`XlaRuntime`].

use crate::error::{Error, Result};
use crate::util::json::{self, Json};
use std::path::{Path, PathBuf};

/// Parameter/output description from the manifest.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// One AOT artifact's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub entry: String,
    pub file: String,
    pub params: Vec<TensorSpec>,
    pub n: usize,
    pub buckets: Option<usize>,
    pub block: Option<usize>,
}

fn tensor_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    let arr = j
        .as_arr()
        .ok_or_else(|| Error::Artifact("params not an array".into()))?;
    arr.iter()
        .map(|p| {
            Ok(TensorSpec {
                name: p
                    .get("name")
                    .and_then(|n| n.as_str())
                    .unwrap_or("?")
                    .to_string(),
                shape: p
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .map(|dims| {
                        dims.iter()
                            .filter_map(|d| d.as_u64())
                            .map(|d| d as usize)
                            .collect()
                    })
                    .unwrap_or_default(),
            })
        })
        .collect()
}

/// Parse `manifest.json` into artifact metadata.
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactMeta>> {
    let doc = json::parse(text).map_err(|e| Error::Artifact(e.to_string()))?;
    let obj = doc
        .as_obj()
        .ok_or_else(|| Error::Artifact("manifest is not an object".into()))?;
    let mut out = Vec::new();
    for (name, entry) in obj {
        out.push(ArtifactMeta {
            name: name.clone(),
            entry: entry
                .get("entry")
                .and_then(|e| e.as_str())
                .ok_or_else(|| Error::Artifact(format!("{name}: missing entry")))?
                .to_string(),
            file: entry
                .get("file")
                .and_then(|e| e.as_str())
                .ok_or_else(|| Error::Artifact(format!("{name}: missing file")))?
                .to_string(),
            params: tensor_specs(
                entry
                    .get("params")
                    .ok_or_else(|| Error::Artifact(format!("{name}: missing params")))?,
            )?,
            n: entry
                .get("n")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| Error::Artifact(format!("{name}: missing n")))?
                as usize,
            buckets: entry
                .get("buckets")
                .and_then(|v| v.as_u64())
                .map(|v| v as usize),
            block: entry
                .get("block")
                .and_then(|v| v.as_u64())
                .map(|v| v as usize),
        });
    }
    Ok(out)
}

/// The compute interface of the sort application: classify keys into
/// buckets, and produce a stable sort permutation.
pub trait SortCompute {
    /// `bounds` are ascending bucket boundaries; returns
    /// `(bucket id per key, histogram of len(bounds)+1)`.
    fn partition(&self, keys: &[i32], bounds: &[i32]) -> Result<(Vec<u32>, Vec<u64>)>;
    /// Stable argsort: `perm[i]` = original index of i-th smallest key.
    fn argsort(&self, keys: &[i32]) -> Result<Vec<u32>>;
    /// Human-readable backend name (logged by the harness).
    fn name(&self) -> &'static str;
}

/// Pure-rust reference implementation — the oracle the XLA path is
/// validated against, and the fallback when artifacts are absent.
#[derive(Debug, Default)]
pub struct NativeCompute;

impl SortCompute for NativeCompute {
    fn partition(&self, keys: &[i32], bounds: &[i32]) -> Result<(Vec<u32>, Vec<u64>)> {
        let mut hist = vec![0u64; bounds.len() + 1];
        let ids = keys
            .iter()
            .map(|k| {
                let b = bounds.partition_point(|bound| bound <= k) as u32;
                hist[b as usize] += 1;
                b
            })
            .collect();
        Ok((ids, hist))
    }

    fn argsort(&self, keys: &[i32]) -> Result<Vec<u32>> {
        let mut perm: Vec<u32> = (0..keys.len() as u32).collect();
        perm.sort_by_key(|&i| (keys[i as usize], i));
        Ok(perm)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// A compiled artifact ready to execute.
#[cfg(feature = "xla-runtime")]
struct Loaded {
    meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client, one compiled executable per model
/// variant, loaded once at startup.
#[cfg(feature = "xla-runtime")]
pub struct XlaRuntime {
    partition_variants: Vec<Loaded>,
    sort_variants: Vec<Loaded>,
}

/// Stub runtime for builds without the `xla-runtime` feature (the
/// offline default: the vendored `xla` crate is unavailable).  Loading
/// always fails cleanly, so every caller falls back to
/// [`NativeCompute`].
#[cfg(not(feature = "xla-runtime"))]
pub struct XlaRuntime {
    _unconstructible: std::convert::Infallible,
}

#[cfg(not(feature = "xla-runtime"))]
impl XlaRuntime {
    /// Default artifact location (relative to the repo root).
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Always fails: this build has no PJRT backend.
    pub fn load(dir: &Path) -> Result<XlaRuntime> {
        let _ = dir;
        Err(Error::Artifact(
            "built without the `xla-runtime` feature; rebuild with \
             --features xla-runtime (requires the vendored xla crate)"
                .into(),
        ))
    }

    /// Always fails: this build has no PJRT backend.
    pub fn load_default() -> Result<XlaRuntime> {
        Self::load(&Self::default_dir())
    }

    /// Artifact inventory (empty: the stub cannot be constructed).
    pub fn inventory(&self) -> Vec<&ArtifactMeta> {
        Vec::new()
    }
}

#[cfg(not(feature = "xla-runtime"))]
impl SortCompute for XlaRuntime {
    fn partition(&self, _keys: &[i32], _bounds: &[i32]) -> Result<(Vec<u32>, Vec<u64>)> {
        match self._unconstructible {}
    }

    fn argsort(&self, _keys: &[i32]) -> Result<Vec<u32>> {
        match self._unconstructible {}
    }

    fn name(&self) -> &'static str {
        "xla-unavailable"
    }
}

#[cfg(feature = "xla-runtime")]
impl XlaRuntime {
    /// Default artifact location (relative to the repo root).
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Load every artifact in `dir` per its manifest.
    pub fn load(dir: &Path) -> Result<XlaRuntime> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts`): {e}",
                manifest_path.display()
            ))
        })?;
        let metas = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu()?;
        let mut partition_variants = Vec::new();
        let mut sort_variants = Vec::new();
        for meta in metas {
            let path = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::Artifact("non-utf8 path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            let loaded = Loaded { meta, exe };
            match loaded.meta.entry.as_str() {
                "plan_partition" => partition_variants.push(loaded),
                "plan_sort" | "plan_sort_blocked" => sort_variants.push(loaded),
                other => {
                    return Err(Error::Artifact(format!("unknown entry {other}")));
                }
            }
        }
        // Prefer the smallest sufficient variant at dispatch time.
        partition_variants.sort_by_key(|l| l.meta.n);
        sort_variants.sort_by_key(|l| sort_capacity(&l.meta));
        if partition_variants.is_empty() || sort_variants.is_empty() {
            return Err(Error::Artifact(
                "manifest has no partition/sort variants".into(),
            ));
        }
        Ok(XlaRuntime {
            partition_variants,
            sort_variants,
        })
    }

    /// Load from the default directory.
    pub fn load_default() -> Result<XlaRuntime> {
        Self::load(&Self::default_dir())
    }

    /// Artifact inventory (for the CLI's `artifacts` subcommand).
    pub fn inventory(&self) -> Vec<&ArtifactMeta> {
        self.partition_variants
            .iter()
            .chain(self.sort_variants.iter())
            .map(|l| &l.meta)
            .collect()
    }

    fn run2(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let t = result.to_tuple()?;
        if t.len() != 2 {
            return Err(Error::Artifact(format!(
                "expected 2 outputs, got {}",
                t.len()
            )));
        }
        Ok((t[0].to_vec::<i32>()?, t[1].to_vec::<i32>()?))
    }
}

/// How many keys one call of a sort artifact can sort independently.
#[cfg(feature = "xla-runtime")]
fn sort_capacity(meta: &ArtifactMeta) -> usize {
    meta.block.unwrap_or(meta.n)
}

#[cfg(feature = "xla-runtime")]
impl SortCompute for XlaRuntime {
    fn partition(&self, keys: &[i32], bounds: &[i32]) -> Result<(Vec<u32>, Vec<u64>)> {
        let logical = bounds.len() + 1;
        // Smallest variant with at least `logical` buckets; the bounds are
        // padded with i32::MAX so the surplus buckets receive only pads.
        let variant = self
            .partition_variants
            .iter()
            .find(|l| l.meta.buckets.unwrap_or(0) >= logical)
            .ok_or_else(|| {
                Error::Artifact(format!("no partition artifact with >= {logical} buckets"))
            })?;
        let art_buckets = variant.meta.buckets.unwrap();
        let mut padded_bounds = bounds.to_vec();
        padded_bounds.resize(art_buckets - 1, i32::MAX);
        let n = variant.meta.n;
        let bounds_lit = xla::Literal::vec1(&padded_bounds);
        let mut ids = Vec::with_capacity(keys.len());
        let mut hist = vec![0u64; logical];
        for chunk in keys.chunks(n) {
            let mut padded = chunk.to_vec();
            padded.resize(n, i32::MAX);
            let keys_lit = xla::Literal::vec1(&padded);
            let (chunk_ids, chunk_hist) =
                Self::run2(&variant.exe, &[keys_lit, bounds_lit.clone()])?;
            // Clamp ids into the logical bucket range: a real key that is
            // >= every real bound may spill past `logical - 1` when the
            // pad bound equals i32::MAX and the key does too.
            ids.extend(
                chunk_ids[..chunk.len()]
                    .iter()
                    .map(|&b| (b as u32).min(logical as u32 - 1)),
            );
            // Fold the surplus buckets into the logical last one, then
            // remove the pads (which all land in the artifact's top).
            for (b, c) in chunk_hist.iter().enumerate() {
                let lb = b.min(logical - 1);
                hist[lb] += *c as u64;
            }
            let pad = (n - chunk.len()) as u64;
            hist[logical - 1] -= pad;
        }
        Ok((ids, hist))
    }

    fn argsort(&self, keys: &[i32]) -> Result<Vec<u32>> {
        if keys.is_empty() {
            return Ok(Vec::new());
        }
        // Smallest variant whose independent tile fits all keys.
        let variant = self
            .sort_variants
            .iter()
            .find(|l| sort_capacity(&l.meta) >= keys.len())
            .or_else(|| self.sort_variants.last())
            .unwrap();
        let tile = sort_capacity(&variant.meta);
        if keys.len() > tile {
            // Merge path: sort tile-sized chunks on the device, then do a
            // stable k-way merge of the permutations host-side.
            return merge_argsort(self, keys, tile);
        }
        let mut padded = keys.to_vec();
        padded.resize(variant.meta.n, i32::MAX);
        let keys_lit = xla::Literal::vec1(&padded);
        let (_sorted, perm) = Self::run2(&variant.exe, &[keys_lit])?;
        // Keep only indices of real keys: pads have index >= len and the
        // composite (key, index) order puts them after every real entry
        // with the same key.
        Ok(perm
            .into_iter()
            .filter(|&i| (i as usize) < keys.len())
            .map(|i| i as u32)
            .take(keys.len())
            .collect())
    }

    fn name(&self) -> &'static str {
        "xla-pjrt"
    }
}

/// Stable k-way merge of device-sorted tiles (for inputs larger than the
/// biggest artifact tile).
#[cfg(feature = "xla-runtime")]
fn merge_argsort(rt: &XlaRuntime, keys: &[i32], tile: usize) -> Result<Vec<u32>> {
    let mut runs: Vec<Vec<u32>> = Vec::new();
    for (t, chunk) in keys.chunks(tile).enumerate() {
        let perm = rt.argsort(chunk)?;
        runs.push(perm.into_iter().map(|i| i + (t * tile) as u32).collect());
    }
    // K-way merge with (key, global index) ordering for stability.
    let mut heads = vec![0usize; runs.len()];
    let mut out = Vec::with_capacity(keys.len());
    loop {
        let mut best: Option<(i32, u32, usize)> = None;
        for (r, run) in runs.iter().enumerate() {
            if heads[r] < run.len() {
                let idx = run[heads[r]];
                let cand = (keys[idx as usize], idx, r);
                let better = match best {
                    Some((bk, bi, _)) => (cand.0, cand.1) < (bk, bi),
                    None => true,
                };
                if better {
                    best = Some(cand);
                }
            }
        }
        match best {
            Some((_, idx, r)) => {
                out.push(idx);
                heads[r] += 1;
            }
            None => break,
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_partition_matches_definition() {
        let nc = NativeCompute;
        let (ids, hist) = nc.partition(&[5, 0, 99, 42, 10], &[10, 50]).unwrap();
        assert_eq!(ids, vec![0, 0, 2, 1, 1]);
        assert_eq!(hist, vec![2, 2, 1]);
        // Empty bounds: one bucket.
        let (ids, hist) = nc.partition(&[1, 2], &[]).unwrap();
        assert_eq!(ids, vec![0, 0]);
        assert_eq!(hist, vec![2]);
    }

    #[test]
    fn native_argsort_is_stable() {
        let nc = NativeCompute;
        let perm = nc.argsort(&[3, 1, 3, 0]).unwrap();
        assert_eq!(perm, vec![3, 1, 0, 2]);
        assert_eq!(nc.argsort(&[]).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn manifest_parses() {
        let text = r#"{
            "partition_n16384_b16": {
                "entry": "plan_partition",
                "file": "partition_n16384_b16.hlo.txt",
                "n": 16384, "buckets": 16,
                "params": [
                    {"name": "keys", "shape": [16384], "dtype": "i32"},
                    {"name": "bounds", "shape": [15], "dtype": "i32"}
                ],
                "outputs": []
            },
            "sort_n1024": {
                "entry": "plan_sort",
                "file": "sort_n1024.hlo.txt",
                "n": 1024,
                "params": [{"name": "keys", "shape": [1024], "dtype": "i32"}],
                "outputs": []
            }
        }"#;
        let metas = parse_manifest(text).unwrap();
        assert_eq!(metas.len(), 2);
        let p = metas.iter().find(|m| m.entry == "plan_partition").unwrap();
        assert_eq!(p.n, 16384);
        assert_eq!(p.buckets, Some(16));
        assert_eq!(p.params[1].shape, vec![15]);
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(parse_manifest("[]").is_err());
        assert!(parse_manifest(r#"{"x": {"entry": "plan_sort"}}"#).is_err());
    }

    // The XLA-backed paths are exercised by rust/tests/integration.rs,
    // which requires `make artifacts` to have run.
}
