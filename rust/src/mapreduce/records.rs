//! Record-oriented file format for the sort benchmark (§4.1): fixed-size
//! records, each keyed by its first four bytes (big-endian, non-negative
//! — the paper uses 10 B keys on 500 kB records; we use 4 B keys so they
//! map 1:1 onto the kernels' int32 lanes).

use crate::util::Rng;

/// Fixed-size record layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecordFormat {
    /// Total record size in bytes (key included). Paper: 500 kB.
    pub record_size: usize,
}

impl RecordFormat {
    pub fn new(record_size: usize) -> Self {
        assert!(record_size >= 4, "records must fit a 4-byte key");
        RecordFormat { record_size }
    }

    /// Number of whole records in `len` bytes.
    pub fn count(&self, len: u64) -> u64 {
        len / self.record_size as u64
    }
}

/// Key of the record starting at `data[at..]`.
pub fn key_of(data: &[u8], at: usize) -> i32 {
    i32::from_be_bytes([data[at], data[at + 1], data[at + 2], data[at + 3]]) & i32::MAX
}

/// Extract every record key from a buffer of whole records.
pub fn extract_keys(data: &[u8], fmt: RecordFormat) -> Vec<i32> {
    debug_assert_eq!(data.len() % fmt.record_size, 0);
    (0..data.len() / fmt.record_size)
        .map(|r| key_of(data, r * fmt.record_size))
        .collect()
}

/// Generate `count` records with uniformly random non-negative keys and
/// random payloads (deterministic per seed).
pub fn generate_records(count: u64, fmt: RecordFormat, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    let mut out = vec![0u8; count as usize * fmt.record_size];
    for r in 0..count as usize {
        let at = r * fmt.record_size;
        let key = (rng.next_u64() as u32 & i32::MAX as u32) as i32;
        out[at..at + 4].copy_from_slice(&key.to_be_bytes());
        rng.fill_bytes(&mut out[at + 4..at + fmt.record_size]);
    }
    out
}

/// Evenly-spaced bucket boundaries over the non-negative int32 keyspace.
pub fn bucket_bounds(num_buckets: usize) -> Vec<i32> {
    assert!(num_buckets >= 1);
    let width = (i32::MAX as i64 + 1) / num_buckets as i64;
    (1..num_buckets as i64).map(|i| (i * width) as i32).collect()
}

/// True when the records in `data` are in non-decreasing key order.
pub fn is_sorted(data: &[u8], fmt: RecordFormat) -> bool {
    let keys = extract_keys(data, fmt);
    keys.windows(2).all(|w| w[0] <= w[1])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic_and_well_formed() {
        let fmt = RecordFormat::new(64);
        let a = generate_records(100, fmt, 7);
        let b = generate_records(100, fmt, 7);
        assert_eq!(a, b);
        assert_eq!(a.len(), 6400);
        let keys = extract_keys(&a, fmt);
        assert_eq!(keys.len(), 100);
        assert!(keys.iter().all(|&k| k >= 0));
        assert_ne!(a, generate_records(100, fmt, 8));
    }

    #[test]
    fn bounds_partition_the_keyspace() {
        let b = bucket_bounds(4);
        assert_eq!(b.len(), 3);
        assert!(b.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(bucket_bounds(1), Vec::<i32>::new());
    }

    #[test]
    fn sortedness_check() {
        let fmt = RecordFormat::new(8);
        let mut data = Vec::new();
        for k in [3i32, 5, 5, 9] {
            data.extend_from_slice(&k.to_be_bytes());
            data.extend_from_slice(&[0; 4]);
        }
        assert!(is_sorted(&data, fmt));
        let mut unsorted = data.clone();
        unsorted[0..4].copy_from_slice(&10i32.to_be_bytes());
        assert!(!is_sorted(&unsorted, fmt));
    }
}
