//! A bulk-I/O facade over both filesystems so the *conventional* sorter
//! is byte-for-byte identical on WTF and hdfs-lite — the apples-to-apples
//! requirement of §4.
//!
//! The facade is append-only + positional-read, i.e. exactly the subset
//! HDFS supports; the slicing sorter bypasses it and talks to the WTF
//! client directly.

use crate::baseline::HdfsClient;
use crate::client::WtfClient;
use crate::error::Result;

/// Append-only bulk file operations (the HDFS-compatible subset).
pub trait BulkFs {
    /// Create `path` and write all of `data` (single-writer, sequential).
    fn write_file(&self, path: &str, data: &[u8]) -> Result<()>;
    /// Append `data` to `path`, creating it if missing.
    fn append_file(&self, path: &str, data: &[u8]) -> Result<()>;
    /// Positional read.
    fn read_range(&self, path: &str, offset: u64, len: u64) -> Result<Vec<u8>>;
    /// Visible length.
    fn file_len(&self, path: &str) -> Result<u64>;
    /// Remove a file.
    fn remove(&self, path: &str) -> Result<()>;
    /// Backend label for harness output.
    fn backend(&self) -> &'static str;
}

impl BulkFs for WtfClient {
    fn write_file(&self, path: &str, data: &[u8]) -> Result<()> {
        let mut fd = self.create(path)?;
        self.write(&mut fd, data)
    }

    fn append_file(&self, path: &str, data: &[u8]) -> Result<()> {
        let fd = self.open_or_create(path)?;
        self.append_bytes(&fd, data)?;
        Ok(())
    }

    fn read_range(&self, path: &str, offset: u64, len: u64) -> Result<Vec<u8>> {
        let fd = self.open(path)?;
        self.read_at(&fd, offset, len)
    }

    fn file_len(&self, path: &str) -> Result<u64> {
        Ok(self.stat(path)?.len)
    }

    fn remove(&self, path: &str) -> Result<()> {
        self.unlink(path)
    }

    fn backend(&self) -> &'static str {
        "wtf"
    }
}

impl BulkFs for HdfsClient {
    fn write_file(&self, path: &str, data: &[u8]) -> Result<()> {
        let mut w = self.create(path)?;
        w.write(data)?;
        // Match WTF's visibility guarantee per the paper's methodology:
        // every write is followed by hflush.
        w.close()
    }

    fn append_file(&self, path: &str, data: &[u8]) -> Result<()> {
        let mut w = if self.exists(path) {
            self.append(path)?
        } else {
            self.create(path)?
        };
        w.write(data)?;
        w.close()
    }

    fn read_range(&self, path: &str, offset: u64, len: u64) -> Result<Vec<u8>> {
        self.read_at(path, offset, len)
    }

    fn file_len(&self, path: &str) -> Result<u64> {
        self.len(path)
    }

    fn remove(&self, path: &str) -> Result<()> {
        self.delete(path)
    }

    fn backend(&self) -> &'static str {
        "hdfs"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::{HdfsCluster, HdfsConfig};
    use crate::client::testutil::small_cluster;
    use crate::net::LinkModel;

    fn exercise<F: BulkFs>(fs: &F) {
        fs.write_file("/bulk", b"0123456789").unwrap();
        assert_eq!(fs.file_len("/bulk").unwrap(), 10);
        fs.append_file("/bulk", b"ab").unwrap();
        assert_eq!(fs.read_range("/bulk", 8, 4).unwrap(), b"89ab");
        fs.append_file("/fresh", b"new").unwrap();
        assert_eq!(fs.read_range("/fresh", 0, 3).unwrap(), b"new");
        fs.remove("/bulk").unwrap();
        assert!(fs.file_len("/bulk").is_err());
    }

    #[test]
    fn wtf_facade() {
        let cluster = small_cluster();
        exercise(&cluster.client());
    }

    #[test]
    fn hdfs_facade() {
        let cluster =
            HdfsCluster::new(HdfsConfig::test(), None, LinkModel::instant()).unwrap();
        exercise(&cluster.client());
    }
}
