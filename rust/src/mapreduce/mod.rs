//! The map-reduce sort application of the evaluation (§4.1): bucketing →
//! per-bucket sort → merge, in two implementations.
//!
//! * **Conventional** ([`sort::sort_conventional`]) — reads and writes
//!   record *bytes* at every stage, over any [`bulkfs::BulkFs`] (WTF or
//!   hdfs-lite).  Table 2's left column: 300 GB read + 300 GB written
//!   for a 100 GB sort.
//! * **File slicing** ([`sort::sort_slicing`]) — WTF only: bucketing
//!   *pastes* record slices, sorting rearranges slices by the kernel's
//!   permutation, merging is `concat`.  Table 2's right column: 200 GB
//!   read, **zero** written.
//!
//! The compute hot-spots (bucket classification, permutation sort) go
//! through [`crate::runtime::SortCompute`] — the AOT-compiled
//! JAX/Pallas kernels in production, the native oracle in unit tests.

pub mod bulkfs;
pub mod records;
pub mod sort;

pub use bulkfs::BulkFs;
pub use records::{extract_keys, generate_records, key_of, RecordFormat};
pub use sort::{
    sort_conventional, sort_conventional_probed, sort_slicing, sort_slicing_probed,
    IoProbe, SortJob, SortStats,
};
