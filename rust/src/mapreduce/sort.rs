//! The sort drivers (§4.1): three stages, two data paths.
//!
//! Stage compute (bucket classification, permutation sort) runs through
//! [`SortCompute`] — the AOT JAX/Pallas kernels in production.  The two
//! drivers differ only in how bytes move:
//!
//! | stage     | conventional       | file slicing                   |
//! |-----------|--------------------|--------------------------------|
//! | bucketing | read R, write R    | read R, **paste pointers**     |
//! | sorting   | read R, write R    | read R, **paste permutation**  |
//! | merging   | read R, write R    | **concat** (metadata only)     |
//!
//! Shuffle reads pipeline across storage servers: a bucket file is a
//! patchwork of slices scattered over the cluster, and the client's
//! gather-read issues every extent fetch concurrently through the
//! transport (one wire time per bucket rather than one per slice).

use super::bulkfs::BulkFs;
use super::records::{bucket_bounds, extract_keys, RecordFormat};
use crate::client::WtfClient;
use crate::error::{Error, Result};
use crate::runtime::SortCompute;
use std::time::{Duration, Instant};

/// Parameters of one sort job.
#[derive(Clone, Debug)]
pub struct SortJob {
    pub fmt: RecordFormat,
    pub num_buckets: usize,
    /// Records processed per streaming chunk during bucketing.
    pub chunk_records: usize,
}

impl SortJob {
    pub fn new(record_size: usize, num_buckets: usize) -> Self {
        SortJob {
            fmt: RecordFormat::new(record_size),
            num_buckets,
            chunk_records: 1024,
        }
    }
}

/// Wall-clock + I/O accounting per stage (Fig. 5's breakdown and
/// Table 2's R/W columns).  I/O tuples are `(bytes read, bytes written)`
/// at the storage layer, filled when a probe is supplied.
#[derive(Clone, Copy, Debug, Default)]
pub struct SortStats {
    pub bucketing: Duration,
    pub sorting: Duration,
    pub merging: Duration,
    pub bucketing_io: (u64, u64),
    pub sorting_io: (u64, u64),
    pub merging_io: (u64, u64),
    pub records: u64,
}

/// Snapshot provider for storage-layer `(bytes_read, bytes_written)` —
/// usually `Cluster::storage_bytes_read/written`.
pub type IoProbe<'a> = &'a dyn Fn() -> (u64, u64);

fn stage_io(probe: Option<IoProbe<'_>>, before: (u64, u64)) -> (u64, u64) {
    match probe {
        Some(p) => {
            let now = p();
            (now.0 - before.0, now.1 - before.1)
        }
        None => (0, 0),
    }
}

fn probe_now(probe: Option<IoProbe<'_>>) -> (u64, u64) {
    probe.map(|p| p()).unwrap_or((0, 0))
}

impl SortStats {
    pub fn total(&self) -> Duration {
        self.bucketing + self.sorting + self.merging
    }
}

fn bucket_path(base: &str, b: usize) -> String {
    format!("{base}.bucket{b:04}")
}

fn sorted_path(base: &str, b: usize) -> String {
    format!("{base}.sorted{b:04}")
}

/// Conventional sorter: every stage reads and writes record bytes.
/// Works on any [`BulkFs`] (WTF and hdfs-lite).
pub fn sort_conventional<F: BulkFs>(
    fs: &F,
    compute: &dyn SortCompute,
    input: &str,
    output: &str,
    job: &SortJob,
) -> Result<SortStats> {
    sort_conventional_probed(fs, compute, input, output, job, None)
}

/// [`sort_conventional`] with a storage I/O probe for per-stage R/W
/// accounting (Table 2).
pub fn sort_conventional_probed<F: BulkFs>(
    fs: &F,
    compute: &dyn SortCompute,
    input: &str,
    output: &str,
    job: &SortJob,
    probe: Option<IoProbe<'_>>,
) -> Result<SortStats> {
    let mut stats = SortStats::default();
    let bounds = bucket_bounds(job.num_buckets);
    let rs = job.fmt.record_size;
    let input_len = fs.file_len(input)?;
    let total_records = job.fmt.count(input_len);
    stats.records = total_records;

    // ---- Stage 1: bucketing (map) — read input, write bucket files.
    let t0 = Instant::now();
    let io0 = probe_now(probe);
    let chunk_bytes = (job.chunk_records * rs) as u64;
    let mut offset = 0u64;
    let mut bucket_buffers: Vec<Vec<u8>> = vec![Vec::new(); job.num_buckets];
    while offset < input_len {
        let take = chunk_bytes.min(input_len - offset);
        let data = fs.read_range(input, offset, take)?;
        let keys = extract_keys(&data, job.fmt);
        let (ids, _hist) = compute.partition(&keys, &bounds)?;
        for (r, &b) in ids.iter().enumerate() {
            bucket_buffers[b as usize]
                .extend_from_slice(&data[r * rs..(r + 1) * rs]);
        }
        for (b, buf) in bucket_buffers.iter_mut().enumerate() {
            if !buf.is_empty() {
                fs.append_file(&bucket_path(output, b), buf)?;
                buf.clear();
            }
        }
        offset += take;
    }
    stats.bucketing = t0.elapsed();
    stats.bucketing_io = stage_io(probe, io0);

    // ---- Stage 2: per-bucket sort — read bucket, write sorted bytes.
    let t1 = Instant::now();
    let io1 = probe_now(probe);
    for b in 0..job.num_buckets {
        let path = bucket_path(output, b);
        let len = match fs.file_len(&path) {
            Ok(l) => l,
            Err(Error::NotFound(_)) => continue,
            Err(e) => return Err(e),
        };
        let data = fs.read_range(&path, 0, len)?;
        let keys = extract_keys(&data, job.fmt);
        let perm = compute.argsort(&keys)?;
        let mut sorted = vec![0u8; data.len()];
        for (i, &src) in perm.iter().enumerate() {
            sorted[i * rs..(i + 1) * rs]
                .copy_from_slice(&data[src as usize * rs..(src as usize + 1) * rs]);
        }
        fs.write_file(&sorted_path(output, b), &sorted)?;
        fs.remove(&path)?;
    }
    stats.sorting = t1.elapsed();
    stats.sorting_io = stage_io(probe, io1);

    // ---- Stage 3: merge (reduce) — buckets hold disjoint key ranges,
    // so merging is sequential concatenation ... by copying bytes.
    let t2 = Instant::now();
    let io2 = probe_now(probe);
    for b in 0..job.num_buckets {
        let path = sorted_path(output, b);
        let len = match fs.file_len(&path) {
            Ok(l) => l,
            Err(Error::NotFound(_)) => continue,
            Err(e) => return Err(e),
        };
        // Stream in chunks to bound memory.
        let mut off = 0u64;
        while off < len {
            let take = chunk_bytes.min(len - off);
            let data = fs.read_range(&path, off, take)?;
            fs.append_file(output, &data)?;
            off += take;
        }
        fs.remove(&path)?;
    }
    stats.merging = t2.elapsed();
    stats.merging_io = stage_io(probe, io2);
    Ok(stats)
}

/// File-slicing sorter (WTF only): bytes are read exactly once (to see
/// the keys); every write is a metadata paste; the merge is `concat`.
pub fn sort_slicing(
    client: &WtfClient,
    compute: &dyn SortCompute,
    input: &str,
    output: &str,
    job: &SortJob,
) -> Result<SortStats> {
    sort_slicing_probed(client, compute, input, output, job, None)
}

/// [`sort_slicing`] with a storage I/O probe (Table 2).
pub fn sort_slicing_probed(
    client: &WtfClient,
    compute: &dyn SortCompute,
    input: &str,
    output: &str,
    job: &SortJob,
    probe: Option<IoProbe<'_>>,
) -> Result<SortStats> {
    let mut stats = SortStats::default();
    let bounds = bucket_bounds(job.num_buckets);
    let rs = job.fmt.record_size as u64;
    let in_fd = client.open(input)?;
    let input_len = client.len(&in_fd)?;
    stats.records = job.fmt.count(input_len);

    // Intermediate files are unreplicated: "they may easily be recomputed
    // from the input" (§4.1).
    for b in 0..job.num_buckets {
        client.create_with_replication(&bucket_path(output, b), 1)?;
    }

    // ---- Stage 1: bucketing — read record keys, paste record slices.
    let t0 = Instant::now();
    let io0 = probe_now(probe);
    let chunk_bytes = (job.chunk_records as u64) * rs;
    let mut offset = 0u64;
    while offset < input_len {
        let take = chunk_bytes.min(input_len - offset);
        let data = client.read_at(&in_fd, offset, take)?;
        let keys = extract_keys(&data, job.fmt);
        let chunk_slice = client.yank_at(in_fd.inode(), offset, take)?;
        let (ids, _hist) = compute.partition(&keys, &bounds)?;
        // Coalesce runs of same-bucket records into single sub-slices.
        let mut per_bucket: Vec<crate::client::Slice> =
            vec![Default::default(); job.num_buckets];
        let mut run_start = 0usize;
        for r in 1..=ids.len() {
            if r == ids.len() || ids[r] != ids[run_start] {
                let sub = chunk_slice.sub(run_start as u64 * rs, r as u64 * rs);
                per_bucket[ids[run_start] as usize].extend(&sub);
                run_start = r;
            }
        }
        for (b, slice) in per_bucket.iter().enumerate() {
            if !slice.is_empty() {
                let fd = client.open(&bucket_path(output, b))?;
                client.append_slice(&fd, slice)?;
            }
        }
        offset += take;
    }
    stats.bucketing = t0.elapsed();
    stats.bucketing_io = stage_io(probe, io0);

    // ---- Stage 2: per-bucket sort — read keys, paste the permutation.
    let t1 = Instant::now();
    let io1 = probe_now(probe);
    for b in 0..job.num_buckets {
        let path = bucket_path(output, b);
        let fd = client.open(&path)?;
        let len = client.len(&fd)?;
        if len == 0 {
            continue;
        }
        let data = client.read_at(&fd, 0, len)?;
        let keys = extract_keys(&data, job.fmt);
        let perm = compute.argsort(&keys)?;
        let whole = client.yank_at(fd.inode(), 0, len)?;
        let mut sorted = crate::client::Slice::default();
        for &src in &perm {
            sorted.extend(&whole.sub(u64::from(src) * rs, (u64::from(src) + 1) * rs));
        }
        let out = client.create_with_replication(&sorted_path(output, b), 1)?;
        client.append_slice(&out, &sorted)?;
    }
    stats.sorting = t1.elapsed();
    stats.sorting_io = stage_io(probe, io1);

    // ---- Stage 3: merge — concat, under 1% of the runtime in the paper.
    let t2 = Instant::now();
    let io2 = probe_now(probe);
    let sorted_names: Vec<String> = (0..job.num_buckets)
        .filter(|b| client.exists(&sorted_path(output, *b)))
        .map(|b| sorted_path(output, b))
        .collect();
    let refs: Vec<&str> = sorted_names.iter().map(|s| s.as_str()).collect();
    client.concat(&refs, output)?;
    // Intermediates are no longer needed.
    for b in 0..job.num_buckets {
        let _ = client.unlink(&bucket_path(output, b));
        let _ = client.unlink(&sorted_path(output, b));
    }
    stats.merging = t2.elapsed();
    stats.merging_io = stage_io(probe, io2);
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::{HdfsCluster, HdfsConfig};
    use crate::client::testutil::small_cluster;
    use crate::mapreduce::records::{generate_records, is_sorted};
    use crate::net::LinkModel;
    use crate::runtime::NativeCompute;

    const RECORDS: u64 = 256;
    const RSIZE: usize = 32;

    fn job() -> SortJob {
        let mut j = SortJob::new(RSIZE, 4);
        j.chunk_records = 64;
        j
    }

    #[test]
    fn conventional_sort_on_wtf_is_correct() {
        let cluster = small_cluster();
        let c = cluster.client();
        let data = generate_records(RECORDS, job().fmt, 42);
        c.write_file("/input", &data).unwrap();
        let stats =
            sort_conventional(&c, &NativeCompute, "/input", "/output", &job()).unwrap();
        assert_eq!(stats.records, RECORDS);
        let out = c.read_range("/output", 0, data.len() as u64).unwrap();
        assert_eq!(out.len(), data.len());
        assert!(is_sorted(&out, job().fmt));
    }

    #[test]
    fn conventional_sort_on_hdfs_is_correct() {
        let cluster =
            HdfsCluster::new(HdfsConfig::test(), None, LinkModel::instant()).unwrap();
        let c = cluster.client();
        let data = generate_records(RECORDS, job().fmt, 42);
        c.write_file("/input", &data).unwrap();
        sort_conventional(&c, &NativeCompute, "/input", "/output", &job()).unwrap();
        let out = c.read_range("/output", 0, data.len() as u64).unwrap();
        assert!(is_sorted(&out, job().fmt));
        assert_eq!(out.len(), data.len());
    }

    #[test]
    fn slicing_sort_is_correct_and_writes_nothing() {
        let cluster = small_cluster();
        let c = cluster.client();
        let data = generate_records(RECORDS, job().fmt, 42);
        c.write_file("/input", &data).unwrap();
        let written_before = cluster.storage_bytes_written();
        sort_slicing(&c, &NativeCompute, "/input", "/sorted", &job()).unwrap();
        // Table 2: W = 0 for every slicing stage.
        assert_eq!(cluster.storage_bytes_written(), written_before);
        let fd = c.open("/sorted").unwrap();
        let out = c.read_at(&fd, 0, data.len() as u64).unwrap();
        assert_eq!(out.len(), data.len());
        assert!(is_sorted(&out, job().fmt));
    }

    #[test]
    fn slicing_and_conventional_agree() {
        let cluster = small_cluster();
        let c = cluster.client();
        let data = generate_records(RECORDS, job().fmt, 99);
        c.write_file("/input", &data).unwrap();
        sort_conventional(&c, &NativeCompute, "/input", "/conv", &job()).unwrap();
        sort_slicing(&c, &NativeCompute, "/input", "/slice", &job()).unwrap();
        let a = c.read_range("/conv", 0, data.len() as u64).unwrap();
        let b = c.read_range("/slice", 0, data.len() as u64).unwrap();
        assert_eq!(a, b, "both sorters must produce identical output");
    }

    #[test]
    fn slicing_sort_reads_input_at_most_twice() {
        // Table 2: R = 200 GB for a 100 GB sort (bucketing + sorting),
        // i.e. exactly 2x the input size, vs 3x conventional.
        let cluster = small_cluster();
        let c = cluster.client();
        let data = generate_records(RECORDS, job().fmt, 7);
        c.write_file("/input", &data).unwrap();
        let read_before = cluster.storage_bytes_read();
        sort_slicing(&c, &NativeCompute, "/input", "/out", &job()).unwrap();
        let read = cluster.storage_bytes_read() - read_before;
        assert_eq!(read, 2 * data.len() as u64, "slicing reads exactly 2x");
    }
}
