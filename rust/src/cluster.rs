//! An in-process WTF deployment: coordinator + metadata store + storage
//! servers, assembled per Fig. 1 and handed to clients.
//!
//! One process hosts every component (the offline build has no network),
//! but the component boundaries and protocols are the paper's: servers
//! register with the replicated coordinator, clients bootstrap their
//! placement ring from a coordinator config snapshot, and all filesystem
//! state flows through the metadata/storage services.

use crate::client::WtfClient;
use crate::config::Config;
use crate::coordinator::lease::LeaseClock;
use crate::coordinator::{CoordCmd, Coordinator};
use crate::error::Result;
use crate::meta::{MetaService, MetaStore, MetaTxn, ReplicatedMetaStore};
use crate::meta::MetaOp;
use crate::metrics::Metrics;
use crate::net::{LinkModel, Transport};
use crate::storage::{GcCoordinator, GcReport, Ring, StorageCluster, StorageServer};
use crate::types::{DirEntries, Inode, Key, Value};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Builder for [`Cluster`].
#[derive(Debug, Default)]
pub struct ClusterBuilder {
    config: Config,
    link: LinkModel,
    data_dir: Option<PathBuf>,
}

impl ClusterBuilder {
    pub fn config(mut self, config: Config) -> Self {
        self.config = config;
        self
    }

    pub fn storage_servers(mut self, n: u32) -> Self {
        self.config.storage_servers = n;
        self
    }

    pub fn region_size(mut self, bytes: u64) -> Self {
        self.config.region_size = bytes;
        self
    }

    pub fn replication(mut self, r: u8) -> Self {
        self.config.replication = r;
        self
    }

    /// Simulated network cost per storage transfer (defaults to none).
    pub fn link(mut self, link: LinkModel) -> Self {
        self.link = link;
        self
    }

    /// Size of the transport worker pool (scatter-gather fan-out).
    pub fn transport_workers(mut self, n: u32) -> Self {
        self.config.transport_workers = n;
        self
    }

    /// Serve metadata from per-shard Paxos groups (leader leases,
    /// automatic failover) instead of the in-process chains.
    pub fn replicated_meta(mut self, on: bool) -> Self {
        self.config.meta_paxos = on;
        self
    }

    /// Run multi-shard metadata commits as an intent-logged 2PC over
    /// the Paxos groups (implies nothing by itself — `meta_paxos` must
    /// be on; `Config::validate` enforces the pairing).
    pub fn meta_2pc(mut self, on: bool) -> Self {
        self.config.meta_2pc = on;
        self
    }

    /// Pack concurrently-arriving single-shard metadata commits into
    /// shared Paxos rounds (`Duration::ZERO` = off).
    pub fn group_commit(mut self, window: std::time::Duration, max_txns: usize) -> Self {
        self.config.group_commit_window = window;
        self.config.group_commit_max_txns = max_txns;
        self
    }

    /// Collapse 2PC phase-1/phase-2 proposals into shared transport
    /// scatters (requires `meta_2pc`).
    pub fn prepare_batching(mut self, on: bool) -> Self {
        self.config.prepare_batching = on;
        self
    }

    /// Queue client writes behind a background flusher, reconciling at
    /// flush/commit/close boundaries (CannyFS-style; defaults off).
    pub fn write_behind(mut self, on: bool) -> Self {
        self.config.write_behind = on;
        self
    }

    /// Give every metadata replica an on-disk write-ahead log under
    /// `dir`, so replicas restart from disk instead of rejoining by
    /// peer replay (requires `meta_paxos`; `Config::validate` enforces
    /// the pairing).
    pub fn durable_meta(mut self, dir: PathBuf) -> Self {
        self.config.meta_durable = true;
        self.config.wal_dir = Some(dir);
        self
    }

    /// Put backing files under `dir` instead of a tempdir.
    pub fn data_dir(mut self, dir: PathBuf) -> Self {
        self.data_dir = Some(dir);
        self
    }

    pub fn build(self) -> Result<Cluster> {
        self.config.validate()?;
        let config = self.config;

        // 0. The deployment transport: all cross-component traffic flows
        //    through it, and it owns the simulated link cost.
        let transport = Arc::new(Transport::new(self.link, config.transport_workers));

        // 1. Replicated coordinator; storage servers register with it.
        let coordinator = Arc::new(Coordinator::new(config.coordinator_replicas));
        let mut servers = Vec::with_capacity(config.storage_servers as usize);
        for id in 0..config.storage_servers {
            let dir = self
                .data_dir
                .as_ref()
                .map(|d| d.join(format!("server-{id}")));
            servers.push(Arc::new(StorageServer::new(
                id,
                dir,
                config.backing_files_per_server,
            )?));
            coordinator.call(CoordCmd::RegisterServer { id, weight: 1 })?;
        }
        let storage = Arc::new(StorageCluster::new(servers));

        // 2. Metadata service (hyperdex-lite): chain-replicated shards,
        //    or Paxos shard groups proposing over the deployment
        //    transport when `meta_paxos` is on.
        let meta = if config.meta_paxos {
            let mut store = ReplicatedMetaStore::new(
                config.meta_shards,
                config.meta_group_replicas,
                transport.clone(),
                LeaseClock::auto(),
                config.meta_lease.as_millis() as u64,
            )
            .two_pc(config.meta_2pc)
            .prepare_batching(config.prepare_batching)
            .group_commit(config.group_commit_window, config.group_commit_max_txns)
            .max_clock_skew(config.max_clock_skew.as_millis() as u64);
            if config.meta_durable {
                let dir = config.wal_dir.as_ref().ok_or_else(|| {
                    crate::error::Error::InvalidArgument(
                        "meta_durable requires wal_dir".into(),
                    )
                })?;
                store = store.durable(dir, config.wal_sync, config.wal_checkpoint_every)?;
            }
            Arc::new(MetaService::replicated(
                store,
                config.meta_txn_floor,
                Metrics::new(),
            ))
        } else {
            Arc::new(MetaService::new(
                MetaStore::new(config.meta_shards, config.meta_replicas),
                config.meta_txn_floor,
                Metrics::new(),
            ))
        };

        // 3. Root directory.
        let root = Inode::new_directory(1, 0o755);
        let mut t = MetaTxn::new(meta.clone());
        t.push(MetaOp::PathInsert {
            key: Key::path("/"),
            inode: 1,
            expect_absent: true,
        });
        t.push(MetaOp::Put {
            key: Key::inode(1),
            value: Value::Inode(root),
        });
        t.push(MetaOp::Put {
            key: Key::dir(1),
            value: Value::Dir(DirEntries::new()),
        });
        t.commit()?;

        // 4. Placement ring from the coordinator's config snapshot.
        let snapshot = coordinator.config()?;
        let ring = Ring::new(&snapshot.online_servers, config.ring_vnodes);

        Ok(Cluster {
            config,
            coordinator,
            meta,
            storage,
            ring,
            transport,
            gc: Mutex::new(GcCoordinator::new()),
        })
    }
}

/// A running in-process deployment.
pub struct Cluster {
    config: Config,
    coordinator: Arc<Coordinator>,
    meta: Arc<MetaService>,
    storage: Arc<StorageCluster>,
    ring: Ring,
    transport: Arc<Transport>,
    gc: Mutex<GcCoordinator>,
}

impl Cluster {
    pub fn builder() -> ClusterBuilder {
        ClusterBuilder::default()
    }

    /// A new client bound to this deployment.  All clients share the
    /// deployment transport (and therefore its worker pool and link).
    pub fn client(&self) -> WtfClient {
        WtfClient::with_transport(
            self.config.clone(),
            self.meta.clone(),
            self.storage.clone(),
            self.ring.clone(),
            self.transport.clone(),
        )
    }

    /// The deployment transport.
    pub fn transport(&self) -> &Arc<Transport> {
        &self.transport
    }

    pub fn config(&self) -> &Config {
        &self.config
    }

    pub fn coordinator(&self) -> &Arc<Coordinator> {
        &self.coordinator
    }

    pub fn meta(&self) -> &Arc<MetaService> {
        &self.meta
    }

    pub fn storage(&self) -> &Arc<StorageCluster> {
        &self.storage
    }

    /// Run one garbage-collection round across the cluster (§2.8).  Two
    /// rounds are needed before anything is reclaimed (the safety rule).
    /// Each round re-asserts the PR-9 coexistence bound — with the
    /// versioned cache and scheduled GC both on, `cache_ttl` must sit
    /// strictly inside the scan interval, so no cached region entry can
    /// outlive the two-scan reclamation window.
    pub fn run_gc(&self) -> Result<GcReport> {
        crate::storage::gc::assert_cache_ttl_bound(&self.config);
        self.gc
            .lock()
            .unwrap()
            .run(&*self.meta, &self.storage, Some(&self.transport))
    }

    /// Total transport envelopes sent through this deployment — the
    /// read-path coalescing benchmarks and tests count these (one
    /// `RetrieveMany` replaces many `RetrieveSlice`s; a warm metadata
    /// cache sends no `MetaGet` at all).
    pub fn transport_envelopes(&self) -> u64 {
        self.transport.envelopes_sent()
    }

    /// Envelopes sent on one plane (data, metadata, or Paxos) — the
    /// write-path benchmarks report these separately so a batching win
    /// on the Paxos plane is not diluted by data traffic.
    pub fn transport_envelopes_on(&self, plane: crate::net::Plane) -> u64 {
        self.transport.envelopes_sent_on(plane)
    }

    /// Scatter-gather broadcasts issued through the deployment
    /// transport (prepare batching collapses several per commit).
    pub fn transport_scatters(&self) -> u64 {
        self.transport.scatters_sent()
    }

    /// Aggregate bytes written to all storage servers (Table 2's "W").
    pub fn storage_bytes_written(&self) -> u64 {
        self.storage.iter().map(|s| s.metrics().bytes_written()).sum()
    }

    /// Aggregate bytes read from all storage servers (Table 2's "R").
    pub fn storage_bytes_read(&self) -> u64 {
        self.storage.iter().map(|s| s.metrics().bytes_read()).sum()
    }

    /// Total bytes currently occupying storage (post-GC accounting).
    pub fn storage_bytes_resident(&self) -> u64 {
        self.storage
            .iter()
            .map(|s| s.total_len() - s.metrics().gc_bytes_reclaimed())
            .sum()
    }

    /// Per-shard metadata stats (observability).
    pub fn meta_shard_stats(&self) -> Vec<crate::meta::ShardStats> {
        self.meta.shard_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_assembles_working_cluster() {
        let cluster = Cluster::builder()
            .config(Config::test())
            .storage_servers(3)
            .build()
            .unwrap();
        let c = cluster.client();
        assert!(c.exists("/"));
        let mut fd = c.create("/smoke").unwrap();
        c.write(&mut fd, b"ok").unwrap();
        assert_eq!(c.read_at(&fd, 0, 2).unwrap(), b"ok");
        assert_eq!(cluster.coordinator().config().unwrap().online_servers.len(), 3);
    }

    #[test]
    fn replicated_meta_cluster_works_end_to_end() {
        let cluster = Cluster::builder()
            .config(Config::replicated_test())
            .storage_servers(3)
            .build()
            .unwrap();
        let c = cluster.client();
        let mut fd = c.create("/paxos").unwrap();
        c.write(&mut fd, b"replicated").unwrap();
        assert_eq!(c.read_at(&fd, 0, 10).unwrap(), b"replicated");
        let r = cluster.meta().replicated_store().expect("paxos backend");
        assert!(r.converged(), "all group replicas agree after the workload");
        assert!(r.lease_reads() > 0, "reads were leaseholder-local");
        for s in cluster.meta_shard_stats() {
            assert_eq!(s.total_replicas, 3);
            assert_eq!(s.live_replicas, 3);
        }
    }

    #[test]
    fn two_pc_meta_cluster_works_end_to_end() {
        let cluster = Cluster::builder()
            .config(Config::replicated_2pc_test())
            .storage_servers(3)
            .build()
            .unwrap();
        let c = cluster.client();
        // Multi-file writes exercise multi-shard commits through the
        // intent-logged protocol; bootstrap (root dir) already did.
        let mut fd = c.create("/twopc").unwrap();
        c.write(&mut fd, b"atomic across groups").unwrap();
        assert_eq!(c.read_at(&fd, 0, 20).unwrap(), b"atomic across groups");
        let r = cluster.meta().replicated_store().expect("paxos backend");
        assert!(r.is_two_pc());
        assert!(r.pending_intents().is_empty(), "no intent outlives commit");
        assert!(r.converged());
    }

    #[test]
    fn invalid_config_is_rejected() {
        let mut cfg = Config::test();
        cfg.replication = 10;
        cfg.storage_servers = 2;
        assert!(Cluster::builder().config(cfg).build().is_err());
        // 2PC without the Paxos backend is a config error too.
        let mut cfg = Config::test();
        cfg.meta_2pc = true;
        assert!(Cluster::builder().config(cfg).build().is_err());
        // Durability without a WAL directory has nowhere to log.
        assert!(Cluster::builder().config(Config::durable_test()).build().is_err());
    }

    #[test]
    fn durable_meta_cluster_survives_replica_restart() {
        let dir = crate::util::TempDir::new("wtf-durable-cluster").unwrap();
        let mut cfg = Config::durable_test();
        cfg.wal_dir = Some(dir.path().to_path_buf());
        let cluster = Cluster::builder()
            .config(cfg)
            .storage_servers(3)
            .build()
            .unwrap();
        let c = cluster.client();
        let mut fd = c.create("/durable").unwrap();
        c.write(&mut fd, b"persisted").unwrap();
        let r = cluster.meta().replicated_store().expect("paxos backend");
        assert!(r.is_durable());
        // Tear replica 0 down to its WAL directory and rebuild it from
        // disk alone; the cluster keeps serving and reconverges.
        cluster.meta().restart_replica(0).unwrap();
        assert_eq!(c.read_at(&fd, 0, 9).unwrap(), b"persisted");
        assert!(r.converged(), "restarted replica caught back up");
        // Pointing a differently-shaped cluster at the same WAL root is
        // refused by the cluster marker.
        let mut other = Config::durable_test();
        other.wal_dir = Some(dir.path().to_path_buf());
        other.meta_shards += 1;
        assert!(Cluster::builder().config(other).build().is_err());
    }

    #[test]
    fn gc_end_to_end_reclaims_overwritten_data() {
        let cluster = Cluster::builder().config(Config::test()).build().unwrap();
        let c = cluster.client();
        let f = c.create("/gc").unwrap();
        // Overwrite the same 1 KB ten times: 9 KB of garbage per replica.
        for i in 0..10u8 {
            c.write_at(f.inode(), 0, &[i; 1024]).unwrap();
        }
        // Tier 1: compaction drops the overlaid entries from the metadata
        // list; only then do the old slices become unreferenced (§2.8).
        c.compact_region(crate::types::RegionId::new(f.inode(), 0))
            .unwrap();
        let resident_before = cluster.storage_bytes_resident();
        cluster.run_gc().unwrap(); // scan 1: records only
        let r = cluster.run_gc().unwrap(); // scan 2: collects
        assert!(r.bytes_reclaimed >= 9 * 1024, "reclaimed {}", r.bytes_reclaimed);
        assert!(cluster.storage_bytes_resident() < resident_before);
        // Live contents unharmed.
        assert_eq!(c.read_at(&f, 0, 4).unwrap(), vec![9u8; 4]);
    }

    #[test]
    fn replication_survives_single_server_loss() {
        let cluster = Cluster::builder()
            .config(Config::test())
            .storage_servers(4)
            .replication(2)
            .build()
            .unwrap();
        let c = cluster.client();
        let mut fd = c.create("/dur").unwrap();
        c.write(&mut fd, b"precious data").unwrap();
        // Identify the primary replica's server and kill it by building a
        // storage view without it.
        let (region, _) = c.fetch_region(crate::types::RegionId::new(fd.inode(), 0)).unwrap();
        let primary = match &region.entries[0].data {
            crate::types::SliceData::Stored(v) => v[0].server,
            _ => panic!(),
        };
        let survivors: Vec<_> = cluster
            .storage()
            .iter()
            .filter(|s| s.id() != primary)
            .cloned()
            .collect();
        let degraded = Arc::new(StorageCluster::new(survivors));
        let c2 = WtfClient::new(
            cluster.config().clone(),
            cluster.meta().clone(),
            degraded,
            cluster.client().ring.clone(),
        );
        // Reads fail over to the second replica.
        let fd2 = c2.open("/dur").unwrap();
        assert_eq!(c2.read_at(&fd2, 0, 13).unwrap(), b"precious data");
        // Writes skip the dead server too.
        let mut fd3 = c2.create("/after").unwrap();
        c2.write(&mut fd3, b"still works").unwrap();
        assert_eq!(c2.read_at(&fd3, 0, 11).unwrap(), b"still works");
    }
}
