//! Quickstart: bring up a WTF cluster, use the POSIX API, the slicing
//! API, and a transaction.
//!
//! Run: `cargo run --release --example quickstart`

use wtf::client::SeekFrom;
use wtf::cluster::Cluster;
use wtf::config::Config;

fn main() -> wtf::Result<()> {
    // A 6-server cluster with 2-way replication, tempdir-backed storage.
    let cluster = Cluster::builder()
        .config(Config {
            region_size: 1 << 20,
            storage_servers: 6,
            ..Config::default()
        })
        .build()?;
    let client = cluster.client();

    // --- POSIX-style I/O -------------------------------------------------
    client.mkdir("/home")?;
    let mut fd = client.create("/home/greeting")?;
    client.write(&mut fd, b"Hello, Wave Transactional Filesystem!")?;
    client.seek(&mut fd, SeekFrom::Start(7))?;
    let word = client.read(&mut fd, 4)?;
    assert_eq!(word, b"Wave");
    println!("read back: {}", String::from_utf8_lossy(&word));

    // Random-access writes — the operation HDFS cannot do at all.
    client.write_at(fd.inode(), 7, b"WAVE")?;
    assert_eq!(client.read_at(&fd, 7, 4)?, b"WAVE");

    // --- File slicing (Table 1) ------------------------------------------
    // Move data between files without touching a single data byte.
    let written_before = cluster.storage_bytes_written();
    let slice = client.yank_at(fd.inode(), 7, 4)?;
    let mut copy = client.create("/home/word")?;
    client.paste(&mut copy, &slice)?;
    assert_eq!(client.read_at(&copy, 0, 4)?, b"WAVE");
    assert_eq!(
        cluster.storage_bytes_written(),
        written_before,
        "paste wrote zero bytes to storage"
    );
    println!("yank+paste moved 4 bytes for 0 bytes of storage I/O");

    // concat without reading.
    client.concat(&["/home/word", "/home/word"], "/home/twice")?;
    assert_eq!(client.read_at(&client.open("/home/twice")?, 0, 8)?, b"WAVEWAVE");

    // --- Transactions (§2.6) ---------------------------------------------
    // Atomically move the first 5 bytes of the greeting into a new file.
    let mut t = client.begin();
    let src = t.open("/home/greeting")?;
    let dst = t.create("/home/archived")?;
    let head = t.read(src, 5)?;
    t.write(dst, &head)?;
    t.commit()?;
    assert_eq!(client.read_at(&client.open("/home/archived")?, 0, 5)?, b"Hello");
    println!("transaction committed atomically across two files");

    // --- Garbage collection (§2.8) ----------------------------------------
    client.compact_file(fd.inode(), 64)?;
    cluster.run_gc()?; // scan 1 records
    let gc = cluster.run_gc()?; // scan 2 collects
    println!(
        "GC: reclaimed {} bytes, rewrote {}",
        gc.bytes_reclaimed, gc.bytes_rewritten
    );

    println!("quickstart OK");
    Ok(())
}
