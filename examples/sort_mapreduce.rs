//! The paper's end-to-end workload (§4.1): sort a record file with a
//! map-reduce-style application, comparing the conventional byte-copying
//! pipeline against WTF's file-slicing pipeline — with the compute
//! hot-spots (bucket classification, permutation sort) executed by the
//! AOT-compiled JAX/Pallas kernels through PJRT when available.
//!
//! This is the repository's END-TO-END VALIDATION driver: it runs the
//! full three-layer stack on a real (small) workload and reports the
//! paper's headline metric (I/O bytes + wall clock per stage).
//!
//! Run: `make artifacts && cargo run --release --example sort_mapreduce`

use wtf::bench::stats::{fmt_bytes, fmt_ns};
use wtf::cluster::Cluster;
use wtf::config::Config;
use wtf::mapreduce::bulkfs::BulkFs;
use wtf::mapreduce::records::{generate_records, is_sorted};
use wtf::mapreduce::{sort_conventional_probed, sort_slicing_probed, SortJob, SortStats};
use wtf::runtime::{NativeCompute, SortCompute, XlaRuntime};

const RECORDS: u64 = 16 * 1024;
const RECORD_SIZE: usize = 512; // 8 MB total input
const BUCKETS: usize = 16;

fn report(name: &str, stats: &SortStats, read: u64, written: u64, input: u64) {
    println!(
        "{name:<14} total {:>9}  | bucket {:>9} sort {:>9} merge {:>9} | R {:>9} ({:.1}x) W {:>9}",
        fmt_ns(stats.total().as_nanos() as u64),
        fmt_ns(stats.bucketing.as_nanos() as u64),
        fmt_ns(stats.sorting.as_nanos() as u64),
        fmt_ns(stats.merging.as_nanos() as u64),
        fmt_bytes(read),
        read as f64 / input as f64,
        fmt_bytes(written),
    );
}

fn main() -> wtf::Result<()> {
    // Prefer the real PJRT kernels; fall back to the native oracle with
    // a warning when artifacts are missing.
    let xla;
    let compute: &dyn SortCompute = match XlaRuntime::load_default() {
        Ok(rt) => {
            xla = rt;
            &xla
        }
        Err(e) => {
            eprintln!("WARNING: {e}; using native compute");
            &NativeCompute
        }
    };
    println!("compute backend: {}", compute.name());

    let mut job = SortJob::new(RECORD_SIZE, BUCKETS);
    job.chunk_records = 2048;
    let data = generate_records(RECORDS, job.fmt, 42);
    let input = data.len() as u64;
    println!(
        "input: {} ({} records x {} B keys uniform over int32)\n",
        fmt_bytes(input),
        RECORDS,
        RECORD_SIZE
    );

    let mut outputs = Vec::new();
    for mode in ["conventional", "slicing"] {
        let cluster = Cluster::builder()
            .config(Config {
                region_size: 1 << 21,
                ..Config::default()
            })
            .build()?;
        let c = cluster.client();
        c.write_file("/input", &data)?;
        let (r0, w0) = (cluster.storage_bytes_read(), cluster.storage_bytes_written());
        let probe = {
            let cl = &cluster;
            move || (cl.storage_bytes_read(), cl.storage_bytes_written())
        };
        let stats = if mode == "slicing" {
            sort_slicing_probed(&c, compute, "/input", "/sorted", &job, Some(&probe))?
        } else {
            sort_conventional_probed(&c, compute, "/input", "/sorted", &job, Some(&probe))?
        };
        let read = cluster.storage_bytes_read() - r0;
        let written = cluster.storage_bytes_written() - w0;
        report(mode, &stats, read, written, input);
        let out = c.read_range("/sorted", 0, input)?;
        assert_eq!(out.len() as u64, input, "output truncated");
        assert!(is_sorted(&out, job.fmt), "output NOT sorted");
        outputs.push(out);
    }
    assert_eq!(outputs[0], outputs[1], "modes disagree");
    println!(
        "\nboth pipelines produce identical sorted output; slicing wrote ZERO data bytes (paper Table 2)"
    );
    Ok(())
}
