//! Log-structured store compaction — the paper's motivating use case
//! "garbage collect and compress a database without writing the data"
//! (§1).
//!
//! An append-only key-value log accumulates dead versions; compaction
//! rewrites the log to contain only the live records — but with file
//! slicing the "rewrite" is pure metadata: live records are yanked from
//! the old log and appended to the new one without one byte of data I/O,
//! then the old log is unlinked and the storage GC reclaims it.
//!
//! Run: `cargo run --release --example log_compaction`

use std::collections::HashMap;
use wtf::bench::stats::fmt_bytes;
use wtf::cluster::Cluster;
use wtf::config::Config;
use wtf::util::Rng;

const KEYS: u64 = 64;
const UPDATES: u64 = 1024;
const VALUE_SIZE: usize = 1024;

fn main() -> wtf::Result<()> {
    let cluster = Cluster::builder()
        .config(Config {
            region_size: 1 << 20,
            ..Config::default()
        })
        .build()?;
    let c = cluster.client();

    // 1. Build an append-only log of key updates; most become garbage.
    let log = c.create("/db/log").map_err(|_| ()).unwrap_or_else(|_| {
        c.mkdir("/db").unwrap();
        c.create("/db/log").unwrap()
    });
    let mut rng = Rng::new(11);
    // offset of the LIVE (latest) record per key.
    let mut live: HashMap<u64, u64> = HashMap::new();
    let mut offset = 0u64;
    let rec_len = (8 + VALUE_SIZE) as u64;
    for _ in 0..UPDATES {
        let key = rng.next_below(KEYS);
        let mut rec = key.to_be_bytes().to_vec();
        let mut val = vec![0u8; VALUE_SIZE];
        rng.fill_bytes(&mut val);
        rec.extend_from_slice(&val);
        c.append_bytes(&log, &rec)?;
        live.insert(key, offset);
        offset += rec_len;
    }
    let log_len = c.len(&log)?;
    println!(
        "log: {} updates over {} keys -> {} ({} live)",
        UPDATES,
        KEYS,
        fmt_bytes(log_len),
        fmt_bytes(live.len() as u64 * rec_len)
    );

    // 2. Compact: yank each live record into the new log. ZERO data I/O.
    let (r0, w0) = (cluster.storage_bytes_read(), cluster.storage_bytes_written());
    let compacted = c.create("/db/log.compacted")?;
    let mut keys: Vec<_> = live.keys().copied().collect();
    keys.sort_unstable();
    for k in &keys {
        let rec_slice = c.yank_at(log.inode(), live[k], rec_len)?;
        c.append_slice(&compacted, &rec_slice)?;
    }
    println!(
        "compaction I/O: read {} written {} (both should be 0)",
        fmt_bytes(cluster.storage_bytes_read() - r0),
        fmt_bytes(cluster.storage_bytes_written() - w0),
    );
    assert_eq!(cluster.storage_bytes_written() - w0, 0);

    // 3. Verify the compacted log, then drop the old one.
    for k in &keys {
        let rec = c.read_at(&compacted, keys.binary_search(k).unwrap() as u64 * rec_len, 8)?;
        assert_eq!(u64::from_be_bytes(rec[..8].try_into().unwrap()), *k);
    }
    c.unlink("/db/log")?;

    // 4. Tier-1 metadata compaction + storage GC reclaim the dead bytes.
    c.compact_file(compacted.inode(), 256)?;
    cluster.run_gc()?;
    let gc = cluster.run_gc()?;
    println!(
        "storage GC: reclaimed {} (rewrote only {})",
        fmt_bytes(gc.bytes_reclaimed),
        fmt_bytes(gc.bytes_rewritten)
    );
    assert!(gc.bytes_reclaimed > 0);
    println!("log_compaction OK");
    Ok(())
}
