//! Transactional multi-file ETL — the class of application WTF's
//! transactions enable (§1: "eliminating the possibility of
//! inconsistencies across multiple files").
//!
//! A ledger directory holds one account file per user plus an index
//! file.  Transfers must atomically update two account files and append
//! to the journal; concurrent transfers and a concurrent auditor must
//! never observe money being created or destroyed.
//!
//! Run: `cargo run --release --example transactional_etl`

use std::sync::Arc;
use wtf::client::SeekFrom;
use wtf::cluster::Cluster;
use wtf::config::Config;
use wtf::error::Error;

const ACCOUNTS: usize = 8;
const INITIAL: u64 = 1000;
const TRANSFERS_PER_THREAD: usize = 30;
const THREADS: usize = 4;

fn read_balance(c: &wtf::WtfClient, path: &str) -> wtf::Result<u64> {
    let fd = c.open(path)?;
    let data = c.read_at(&fd, 0, 8)?;
    Ok(u64::from_be_bytes(data[..8].try_into().unwrap()))
}

fn main() -> wtf::Result<()> {
    let cluster = Arc::new(
        Cluster::builder()
            .config(Config {
                region_size: 1 << 16,
                ..Config::test()
            })
            .build()?,
    );
    let c = cluster.client();
    c.mkdir("/bank")?;
    for i in 0..ACCOUNTS {
        let mut fd = c.create(&format!("/bank/acct{i}"))?;
        c.write(&mut fd, &INITIAL.to_be_bytes())?;
    }
    c.create("/bank/journal")?;

    // Concurrent transfer threads.
    let workers: Vec<_> = (0..THREADS)
        .map(|tid| {
            let cluster = cluster.clone();
            std::thread::spawn(move || {
                let c = cluster.client();
                let mut rng = wtf::util::Rng::new(tid as u64 + 1);
                let mut committed = 0u32;
                let mut aborted = 0u32;
                for n in 0..TRANSFERS_PER_THREAD {
                    let from = rng.next_below(ACCOUNTS as u64) as usize;
                    let mut to = rng.next_below(ACCOUNTS as u64) as usize;
                    if to == from {
                        to = (to + 1) % ACCOUNTS;
                    }
                    let amount = 1 + rng.next_below(50);
                    // One WTF transaction across three files.
                    let result = (|| -> wtf::Result<()> {
                        let mut t = c.begin();
                        let fa = t.open(&format!("/bank/acct{from}"))?;
                        let fb = t.open(&format!("/bank/acct{to}"))?;
                        let a = u64::from_be_bytes(
                            t.read(fa, 8)?[..8].try_into().unwrap(),
                        );
                        let b = u64::from_be_bytes(
                            t.read(fb, 8)?[..8].try_into().unwrap(),
                        );
                        if a < amount {
                            t.abort();
                            return Ok(());
                        }
                        t.seek(fa, SeekFrom::Start(0))?;
                        t.write(fa, &(a - amount).to_be_bytes())?;
                        t.seek(fb, SeekFrom::Start(0))?;
                        t.write(fb, &(b + amount).to_be_bytes())?;
                        let j = t.open("/bank/journal")?;
                        t.seek(j, SeekFrom::End(0))?;
                        t.write(
                            j,
                            format!("t{tid}.{n}: {from}->{to} {amount}\n").as_bytes(),
                        )?;
                        t.commit()
                    })();
                    match result {
                        Ok(()) => committed += 1,
                        Err(Error::TxnAborted { .. }) | Err(Error::RetriesExhausted { .. }) => {
                            aborted += 1
                        }
                        Err(e) => panic!("unexpected error: {e}"),
                    }
                }
                (committed, aborted)
            })
        })
        .collect();

    let mut committed = 0;
    let mut aborted = 0;
    for w in workers {
        let (c_, a_) = w.join().unwrap();
        committed += c_;
        aborted += a_;
    }

    // Invariant: total money conserved, regardless of interleaving.
    let total: u64 = (0..ACCOUNTS)
        .map(|i| read_balance(&c, &format!("/bank/acct{i}")).unwrap())
        .sum();
    println!(
        "transfers: {committed} committed, {aborted} aborted-to-application \
         (conflicting reads); retries absorbed {} conflicts",
        c.metrics().txn_retries()
    );
    println!("total balance: {total} (expected {})", ACCOUNTS as u64 * INITIAL);
    assert_eq!(total, ACCOUNTS as u64 * INITIAL, "MONEY NOT CONSERVED");

    let jlen = c.len(&c.open("/bank/journal")?)?;
    println!("journal: {jlen} bytes of audit trail");
    println!("transactional_etl OK");
    Ok(())
}
