#!/usr/bin/env python3
"""Read/write-path bench regression gate (CI bench-smoke job).

Checks a freshly produced BENCH_read_path.json (and, when
--write-fresh / --wal-fresh are given, BENCH_write_path.json /
BENCH_wal.json) for regressions.  All
hard checks are SAME-RUN comparisons, so they are immune to cross-host
wall-clock variance (the committed baseline may have been produced on a
different machine, or be modeled — the authoring container has no Rust
toolchain):

1. Read-path envelope ratios (deterministic counts, always enforced):
     - envelope_ratio_seq  >= --min-seq-ratio (default 4.0, the
       acceptance bound: cached+coalesced whole-file read must issue
       >= 4x fewer transport envelopes than seed);
     - envelope_ratio_sort >= 1.0 (the fast-read sort must not issue
       more envelopes than seed).
2. Write-path batching ratios (deterministic counts, enforced when
   --write-fresh is given):
     - envelope_ratio_batched >= --min-batch-ratio (default 2.0: a
       group-committed N=8 storm must issue >= 2x fewer Paxos-plane
       envelopes than N independent commits);
     - commit_rounds_ratio_storm > 1.0 (the storm must consume fewer
       Paxos commit rounds batched than sequential);
     - scatter_ratio_2pc > 1.0 (prepare batching must issue fewer
       transport scatters, never more).
3. WAL ratios (deterministic counts, enforced when --wal-fresh is
   given):
     - replay_ratio_checkpointed > 1.0 (a checkpointed restart must
       replay strictly fewer records than a full-log restart of the
       same history);
     - fsync_ratio_group_commit > 1.0 (an acked batch under
       sync-always must pay strictly fewer forced syncs than the same
       records appended one-by-one).
4. Chaos convergence ratio (deterministic round counts, enforced when
   --chaos-fresh is given):
     - convergence_ratio > 1.0 (after every seeded partition heals,
       the store must take commits again in strictly fewer rounds
       than the retry budget).
5. Transactional read-through ratios (deterministic envelope counts,
   enforced when --txn-fresh is given):
     - meta_envelope_ratio_concat >= --min-txn-ratio (default 2.0: a
       warm transactional concat must issue >= 2x fewer
       metadata-plane envelopes with the versioned cache than
       without);
     - meta_envelope_ratio_rmw > 1.0 (a warm read-modify-write must
       save at least something).
6. Wall clock, within each fresh file only (enforced when the fresh
   rows are measured, i.e. mean_ns > 0): for each row name present in
   both configs, the fast config must not be more than --max-slowdown
   (default 1.25, i.e. >25%) slower than the seed config measured in
   the SAME run on the SAME machine.

The committed baselines are still loaded and any drift is printed for
trend-watching, but cross-file wall-clock differences never fail the
gate.
"""

import argparse
import json
import sys

# (row, fast config, seed config) pairs compared within one run.
SAME_RUN_PAIRS = [
    ("seq-read-whole-warm", "cache+coalesce", "seed"),
    ("seq-read-stepped-warm", "cache+coalesce+readahead", "seed"),
    ("sort-small", "fast-read", "seed"),
]

# Same-run pairs for the write-path sweep (BENCH_write_path.json).
WRITE_SAME_RUN_PAIRS = [
    ("commit-storm", "group-commit", "seed"),
    ("2pc-cross-shard", "prepare-batching", "seed"),
    ("append-burst", "write-behind", "seed"),
]

# Same-run pairs for the WAL sweep (BENCH_wal.json), keyed by full
# (row, config) since the fast and seed rows use different row names: a
# checkpointed restart of the same 300-record history must not replay
# slower than the full-log restart measured in the same run.
WAL_SAME_RUN_KEY_PAIRS = [
    (("replay-checkpointed", "checkpointed-300"), ("replay", "full-300")),
]


def load(path):
    with open(path) as f:
        return json.load(f)


def rows_by_key(doc):
    return {(r.get("row", ""), r.get("config", "")): r for r in doc.get("rows", [])}


def clock_key_pairs(fresh_rows, key_pairs, max_slowdown, failures):
    """Same-run wall clock over explicit (row, config) key pairs."""
    checked = 0
    for fast_key, seed_key in key_pairs:
        f_row, s_row = fresh_rows.get(fast_key), fresh_rows.get(seed_key)
        if not f_row or not s_row:
            continue
        f_ns, s_ns = f_row.get("mean_ns", 0), s_row.get("mean_ns", 0)
        if not f_ns or not s_ns:
            continue  # modeled rows carry mean_ns = 0
        checked += 1
        slowdown = f_ns / s_ns
        if slowdown > max_slowdown:
            failures.append(
                f"{fast_key[0]} [{fast_key[1]}] is {slowdown:.2f}x "
                f"{seed_key[0]} [{seed_key[1]}] in the same run "
                f"({f_ns:.0f} ns vs {s_ns:.0f} ns; limit {max_slowdown}x)"
            )
    return checked


def clock_pairs(fresh_rows, pairs, max_slowdown, failures):
    """Same-run fast-vs-seed wall clock; returns pairs actually checked."""
    checked = 0
    for row, fast_cfg, seed_cfg in pairs:
        f_row = fresh_rows.get((row, fast_cfg))
        s_row = fresh_rows.get((row, seed_cfg))
        if not f_row or not s_row:
            continue
        f_ns, s_ns = f_row.get("mean_ns", 0), s_row.get("mean_ns", 0)
        if not f_ns or not s_ns:
            continue  # modeled rows carry mean_ns = 0
        checked += 1
        slowdown = f_ns / s_ns
        if slowdown > max_slowdown:
            failures.append(
                f"{row}: [{fast_cfg}] is {slowdown:.2f}x [{seed_cfg}] in the same "
                f"run ({f_ns:.0f} ns vs {s_ns:.0f} ns; limit {max_slowdown}x)"
            )
    return checked


def drift_notes(base, fresh_rows, max_slowdown):
    """Informational only: drift vs the committed baseline."""
    base_rows = rows_by_key(base)
    for key, row in fresh_rows.items():
        b = base_rows.get(key)
        if b and b.get("mean_ns") and row.get("mean_ns"):
            drift = row["mean_ns"] / b["mean_ns"]
            if drift > max_slowdown or drift < 1.0 / max_slowdown:
                print(
                    f"bench_gate: note: {key[0]} [{key[1]}] wall clock {drift:.2f}x "
                    "the committed baseline (informational; cross-host)"
                )


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--baseline", required=True, help="committed BENCH_read_path.json")
    p.add_argument("--fresh", required=True, help="freshly produced BENCH_read_path.json")
    p.add_argument("--write-baseline", help="committed BENCH_write_path.json")
    p.add_argument("--write-fresh", help="freshly produced BENCH_write_path.json")
    p.add_argument("--wal-baseline", help="committed BENCH_wal.json")
    p.add_argument("--wal-fresh", help="freshly produced BENCH_wal.json")
    p.add_argument("--chaos-baseline", help="committed BENCH_chaos.json")
    p.add_argument("--chaos-fresh", help="freshly produced BENCH_chaos.json")
    p.add_argument("--txn-baseline", help="committed BENCH_txn_read.json")
    p.add_argument("--txn-fresh", help="freshly produced BENCH_txn_read.json")
    p.add_argument("--max-slowdown", type=float, default=1.25)
    p.add_argument("--min-seq-ratio", type=float, default=4.0)
    p.add_argument("--min-batch-ratio", type=float, default=2.0)
    p.add_argument("--min-txn-ratio", type=float, default=2.0)
    a = p.parse_args()

    base, fresh = load(a.baseline), load(a.fresh)
    failures = []

    # 1. Envelope ratios (scale-free, deterministic).
    seq = float(fresh.get("envelope_ratio_seq", 0.0))
    if seq < a.min_seq_ratio:
        failures.append(
            f"envelope_ratio_seq {seq:.2f} < {a.min_seq_ratio} "
            "(cached+coalesced read no longer >=4x fewer envelopes than seed)"
        )
    sort_ratio = float(fresh.get("envelope_ratio_sort", 0.0))
    if sort_ratio < 1.0:
        failures.append(
            f"envelope_ratio_sort {sort_ratio:.2f} < 1.0 "
            "(fast-read sort issues MORE envelopes than seed)"
        )

    # 2. Write-path batching ratios (when a write-path file was produced).
    batch_ratio = rounds_ratio = scatter_ratio = None
    write_fresh_rows = {}
    write_base = {}
    if a.write_fresh:
        write_fresh = load(a.write_fresh)
        write_base = load(a.write_baseline) if a.write_baseline else {}
        write_fresh_rows = rows_by_key(write_fresh)
        batch_ratio = float(write_fresh.get("envelope_ratio_batched", 0.0))
        if batch_ratio < a.min_batch_ratio:
            failures.append(
                f"envelope_ratio_batched {batch_ratio:.2f} < {a.min_batch_ratio} "
                "(group-committed storm no longer saves Paxos-plane envelopes)"
            )
        rounds_ratio = float(write_fresh.get("commit_rounds_ratio_storm", 0.0))
        if rounds_ratio <= 1.0:
            failures.append(
                f"commit_rounds_ratio_storm {rounds_ratio:.2f} <= 1.0 "
                "(batched storm consumes as many Paxos rounds as sequential)"
            )
        scatter_ratio = float(write_fresh.get("scatter_ratio_2pc", 0.0))
        if scatter_ratio <= 1.0:
            failures.append(
                f"scatter_ratio_2pc {scatter_ratio:.2f} <= 1.0 "
                "(prepare batching issues as many transport scatters as sequential)"
            )

    # 3. WAL replay ratio (deterministic record counts, when a WAL file
    #    was produced).
    wal_ratio = fsync_ratio = None
    wal_fresh_rows = {}
    wal_base = {}
    if a.wal_fresh:
        wal_fresh = load(a.wal_fresh)
        wal_base = load(a.wal_baseline) if a.wal_baseline else {}
        wal_fresh_rows = rows_by_key(wal_fresh)
        wal_ratio = float(wal_fresh.get("replay_ratio_checkpointed", 0.0))
        if wal_ratio <= 1.0:
            failures.append(
                f"replay_ratio_checkpointed {wal_ratio:.2f} <= 1.0 "
                "(a checkpointed restart no longer replays fewer records "
                "than a full-log restart)"
            )
        fsync_ratio = float(wal_fresh.get("fsync_ratio_group_commit", 0.0))
        if fsync_ratio <= 1.0:
            failures.append(
                f"fsync_ratio_group_commit {fsync_ratio:.2f} <= 1.0 "
                "(an acked batch no longer pays fewer forced syncs than "
                "per-record appends)"
            )

    # 4. Chaos convergence ratio (deterministic round counts, when a
    #    chaos file was produced).
    chaos_ratio = None
    if a.chaos_fresh:
        chaos_fresh = load(a.chaos_fresh)
        chaos_ratio = float(chaos_fresh.get("convergence_ratio", 0.0))
        if chaos_ratio <= 1.0:
            failures.append(
                f"convergence_ratio {chaos_ratio:.2f} <= 1.0 "
                "(post-heal convergence eats the whole retry budget)"
            )
        if a.chaos_baseline:
            chaos_base = load(a.chaos_baseline)
            base_ratio = float(chaos_base.get("convergence_ratio", 0.0))
            if base_ratio and chaos_ratio < base_ratio:
                print(
                    f"bench_gate: note: convergence_ratio {chaos_ratio:.2f} below "
                    f"committed baseline {base_ratio:.2f} (informational; "
                    "round counts are deterministic per seed set)"
                )

    # 5. Transactional read-through ratios (deterministic envelope
    #    counts, when a txn_read file was produced).
    txn_ratio = txn_rmw_ratio = None
    if a.txn_fresh:
        txn_fresh = load(a.txn_fresh)
        txn_ratio = float(txn_fresh.get("meta_envelope_ratio_concat", 0.0))
        if txn_ratio < a.min_txn_ratio:
            failures.append(
                f"meta_envelope_ratio_concat {txn_ratio:.2f} < {a.min_txn_ratio} "
                "(warm transactional concat no longer saves metadata envelopes "
                "through the versioned cache)"
            )
        txn_rmw_ratio = float(txn_fresh.get("meta_envelope_ratio_rmw", 0.0))
        if txn_rmw_ratio <= 1.0:
            failures.append(
                f"meta_envelope_ratio_rmw {txn_rmw_ratio:.2f} <= 1.0 "
                "(warm transactional read-modify-write saves nothing)"
            )
        if a.txn_baseline:
            txn_base = load(a.txn_baseline)
            base_ratio = float(txn_base.get("meta_envelope_ratio_concat", 0.0))
            if base_ratio and txn_ratio < base_ratio:
                print(
                    f"bench_gate: note: meta_envelope_ratio_concat {txn_ratio:.2f} "
                    f"below committed baseline {base_ratio:.2f} (informational; "
                    "envelope counts are deterministic)"
                )

    # 6. Same-run wall clock: fast config vs seed config, one machine.
    fresh_rows = rows_by_key(fresh)
    clock_checked = clock_pairs(fresh_rows, SAME_RUN_PAIRS, a.max_slowdown, failures)
    clock_checked += clock_pairs(
        write_fresh_rows, WRITE_SAME_RUN_PAIRS, a.max_slowdown, failures
    )
    clock_checked += clock_key_pairs(
        wal_fresh_rows, WAL_SAME_RUN_KEY_PAIRS, a.max_slowdown, failures
    )

    # 7. Informational only: drift vs the committed baselines.
    drift_notes(base, fresh_rows, a.max_slowdown)
    if write_fresh_rows:
        drift_notes(write_base, write_fresh_rows, a.max_slowdown)
    if wal_fresh_rows:
        drift_notes(wal_base, wal_fresh_rows, a.max_slowdown)

    if failures:
        print("bench_gate: FAIL")
        for f in failures:
            print(f"  - {f}")
        return 1
    write_part = (
        f", envelope_ratio_batched {batch_ratio:.2f}, "
        f"commit_rounds_ratio_storm {rounds_ratio:.2f}, "
        f"scatter_ratio_2pc {scatter_ratio:.2f}"
        if batch_ratio is not None
        else ""
    )
    wal_part = (
        f", replay_ratio_checkpointed {wal_ratio:.2f}, "
        f"fsync_ratio_group_commit {fsync_ratio:.2f}"
        if wal_ratio is not None
        else ""
    )
    chaos_part = (
        f", convergence_ratio {chaos_ratio:.2f}"
        if chaos_ratio is not None
        else ""
    )
    txn_part = (
        f", meta_envelope_ratio_concat {txn_ratio:.2f}, "
        f"meta_envelope_ratio_rmw {txn_rmw_ratio:.2f}"
        if txn_ratio is not None
        else ""
    )
    print(
        f"bench_gate: OK (envelope_ratio_seq {seq:.2f}, "
        f"envelope_ratio_sort {sort_ratio:.2f}{write_part}{wal_part}{chaos_part}"
        f"{txn_part}, same-run wall-clock pairs checked: {clock_checked})"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
