#!/usr/bin/env python3
"""Read-path bench regression gate (CI bench-smoke job).

Checks a freshly produced BENCH_read_path.json for regressions.  All
hard checks are SAME-RUN comparisons, so they are immune to cross-host
wall-clock variance (the committed baseline may have been produced on a
different machine, or be modeled — the authoring container has no Rust
toolchain):

1. Envelope ratios (deterministic counts, always enforced):
     - envelope_ratio_seq  >= --min-seq-ratio (default 4.0, the
       acceptance bound: cached+coalesced whole-file read must issue
       >= 4x fewer transport envelopes than seed);
     - envelope_ratio_sort >= 1.0 (the fast-read sort must not issue
       more envelopes than seed).
2. Wall clock, within the fresh file only (enforced when the fresh rows
   are measured, i.e. mean_ns > 0): for each row name present in both
   configs, the fast config must not be more than --max-slowdown
   (default 1.25, i.e. >25%) slower than the seed config measured in
   the SAME run on the SAME machine.

The committed baseline is still loaded and any drift is printed for
trend-watching, but cross-file wall-clock differences never fail the
gate.
"""

import argparse
import json
import sys

# (row, fast config, seed config) pairs compared within one run.
SAME_RUN_PAIRS = [
    ("seq-read-whole-warm", "cache+coalesce", "seed"),
    ("seq-read-stepped-warm", "cache+coalesce+readahead", "seed"),
    ("sort-small", "fast-read", "seed"),
]


def load(path):
    with open(path) as f:
        return json.load(f)


def rows_by_key(doc):
    return {(r.get("row", ""), r.get("config", "")): r for r in doc.get("rows", [])}


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--baseline", required=True, help="committed BENCH_read_path.json")
    p.add_argument("--fresh", required=True, help="freshly produced BENCH_read_path.json")
    p.add_argument("--max-slowdown", type=float, default=1.25)
    p.add_argument("--min-seq-ratio", type=float, default=4.0)
    a = p.parse_args()

    base, fresh = load(a.baseline), load(a.fresh)
    failures = []

    # 1. Envelope ratios (scale-free, deterministic).
    seq = float(fresh.get("envelope_ratio_seq", 0.0))
    if seq < a.min_seq_ratio:
        failures.append(
            f"envelope_ratio_seq {seq:.2f} < {a.min_seq_ratio} "
            "(cached+coalesced read no longer >=4x fewer envelopes than seed)"
        )
    sort_ratio = float(fresh.get("envelope_ratio_sort", 0.0))
    if sort_ratio < 1.0:
        failures.append(
            f"envelope_ratio_sort {sort_ratio:.2f} < 1.0 "
            "(fast-read sort issues MORE envelopes than seed)"
        )

    # 2. Same-run wall clock: fast config vs seed config, one machine.
    fresh_rows = rows_by_key(fresh)
    clock_checked = 0
    for row, fast_cfg, seed_cfg in SAME_RUN_PAIRS:
        f_row = fresh_rows.get((row, fast_cfg))
        s_row = fresh_rows.get((row, seed_cfg))
        if not f_row or not s_row:
            continue
        f_ns, s_ns = f_row.get("mean_ns", 0), s_row.get("mean_ns", 0)
        if not f_ns or not s_ns:
            continue  # modeled rows carry mean_ns = 0
        clock_checked += 1
        slowdown = f_ns / s_ns
        if slowdown > a.max_slowdown:
            failures.append(
                f"{row}: [{fast_cfg}] is {slowdown:.2f}x [{seed_cfg}] in the same "
                f"run ({f_ns:.0f} ns vs {s_ns:.0f} ns; limit {a.max_slowdown}x)"
            )

    # 3. Informational only: drift vs the committed baseline.
    base_rows = rows_by_key(base)
    for key, row in fresh_rows.items():
        b = base_rows.get(key)
        if b and b.get("mean_ns") and row.get("mean_ns"):
            drift = row["mean_ns"] / b["mean_ns"]
            if drift > a.max_slowdown or drift < 1.0 / a.max_slowdown:
                print(
                    f"bench_gate: note: {key[0]} [{key[1]}] wall clock {drift:.2f}x "
                    "the committed baseline (informational; cross-host)"
                )

    if failures:
        print("bench_gate: FAIL")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(
        f"bench_gate: OK (envelope_ratio_seq {seq:.2f}, "
        f"envelope_ratio_sort {sort_ratio:.2f}, "
        f"same-run wall-clock pairs checked: {clock_checked})"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
