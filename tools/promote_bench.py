#!/usr/bin/env python3
"""Promote freshly measured bench JSON over the committed baselines.

The committed `BENCH_*.json` baselines were authored in a container
with no Rust toolchain, so their wall-clock rows are marked
`"status": "modeled"` (deterministic count arithmetic, `mean_ns: 0`).
The CI bench-smoke job DOES have cargo: it re-runs every bench binary
and drops the real output into `fresh-bench/`, where each writer emits
the same schema with `"status": "measured"` and nonzero `mean_ns`.

This tool closes the loop: it copies each measured fresh file over the
matching committed baseline, so the repo's baselines graduate from
modeled to measured.  It refuses to promote anything that would make
the baselines LESS honest:

  * a fresh file still marked "modeled" is skipped (promoting it would
    churn the baseline without adding measurement);
  * a fresh file whose rows are all `mean_ns: 0` is rejected even if it
    claims "measured" (a writer bug, not a measurement);
  * a fresh file missing a top-level acceptance-ratio field the
    baseline carries is rejected (schema drift would silently disarm
    tools/bench_gate.py);
  * a fresh file is never promoted over a baseline for a DIFFERENT
    bench (the `bench` field must match).

Modes:

  # In place, on a checkout that has the CI `bench-json` artifact:
  python3 tools/promote_bench.py --fresh-dir fresh-bench

  # CI artifact mode: write promoted baselines into a staging dir and
  # leave the checkout untouched; a maintainer downloads the
  # `promoted-bench` artifact and commits its contents to the repo
  # root.
  python3 tools/promote_bench.py --fresh-dir fresh-bench --out promoted-bench

Exit status is 0 when every present fresh file either promoted or was
legitimately skipped as modeled, and 1 on any rejection.  `--dry-run`
prints the plan without writing.
"""

import argparse
import json
import os
import shutil
import sys

# Every committed baseline the bench-smoke job regenerates.  The fresh
# files carry the same names (see the WTF_BENCH_*_JSON env wiring in
# .github/workflows/ci.yml).
BASELINES = [
    "BENCH_chaos.json",
    "BENCH_client_io.json",
    "BENCH_meta_store.json",
    "BENCH_read_path.json",
    "BENCH_txn_read.json",
    "BENCH_wal.json",
    "BENCH_write_path.json",
]

# Top-level fields the regression gate reads; when the committed
# baseline carries one, the fresh replacement must too.
RATIO_FIELDS = [
    "envelope_ratio_seq",
    "envelope_ratio_sort",
    "envelope_ratio_batched",
    "commit_rounds_ratio_storm",
    "scatter_ratio_2pc",
    "replay_ratio_checkpointed",
    "fsync_ratio_group_commit",
    "convergence_ratio",
    "meta_envelope_ratio_concat",
    "meta_envelope_ratio_rmw",
]


def load(path):
    with open(path) as f:
        return json.load(f)


def check_promotable(name, fresh, baseline):
    """Return (ok, reason). ok=None means 'skip, not an error'."""
    status = fresh.get("status", "")
    if status != "measured":
        return None, f"fresh status is {status!r}, not 'measured'"
    rows = fresh.get("rows", [])
    if not any(r.get("mean_ns", 0) > 0 for r in rows):
        return False, "claims 'measured' but every row has mean_ns 0"
    if baseline is not None:
        if fresh.get("bench") != baseline.get("bench"):
            return False, (
                f"bench mismatch: fresh {fresh.get('bench')!r} vs "
                f"baseline {baseline.get('bench')!r}"
            )
        missing = [
            f for f in RATIO_FIELDS if f in baseline and f not in fresh
        ]
        if missing:
            return False, (
                "fresh file drops gate field(s) the baseline carries: "
                + ", ".join(missing)
            )
    return True, f"measured ({sum(1 for r in rows if r.get('mean_ns', 0) > 0)}/{len(rows)} rows timed)"


def main():
    p = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    p.add_argument(
        "--fresh-dir",
        required=True,
        help="directory of freshly produced BENCH_*.json (CI bench-json artifact)",
    )
    p.add_argument(
        "--baseline-dir",
        default=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        help="directory of the committed baselines (default: repo root)",
    )
    p.add_argument(
        "--out",
        help="write promoted files here instead of over the baselines "
        "(CI artifact mode; the dir is created)",
    )
    p.add_argument("--dry-run", action="store_true", help="print the plan only")
    a = p.parse_args()

    dest_dir = a.out or a.baseline_dir
    promoted, skipped, rejected = [], [], []

    for name in BASELINES:
        fresh_path = os.path.join(a.fresh_dir, name)
        base_path = os.path.join(a.baseline_dir, name)
        if not os.path.exists(fresh_path):
            skipped.append((name, "no fresh file"))
            continue
        try:
            fresh = load(fresh_path)
        except (OSError, json.JSONDecodeError) as e:
            rejected.append((name, f"unreadable fresh file: {e}"))
            continue
        baseline = load(base_path) if os.path.exists(base_path) else None
        ok, reason = check_promotable(name, fresh, baseline)
        if ok is None:
            skipped.append((name, reason))
        elif not ok:
            rejected.append((name, reason))
        else:
            promoted.append((name, reason))
            if not a.dry_run:
                os.makedirs(dest_dir, exist_ok=True)
                shutil.copyfile(fresh_path, os.path.join(dest_dir, name))

    verb = "would promote" if a.dry_run else "promoted"
    for name, reason in promoted:
        print(f"promote_bench: {verb} {name} -> {dest_dir}/ ({reason})")
    for name, reason in skipped:
        print(f"promote_bench: skipped {name} ({reason})")
    for name, reason in rejected:
        print(f"promote_bench: REJECTED {name} ({reason})")

    print(
        f"promote_bench: {len(promoted)} promoted, {len(skipped)} skipped, "
        f"{len(rejected)} rejected"
    )
    return 1 if rejected else 0


if __name__ == "__main__":
    sys.exit(main())
