"""L2: the JAX compute graph of the WTF sort application's hot spots.

The WTF paper's sort (§4.1) is bucketing → per-bucket sort → concat.  The
byte movement lives in the rust filesystem (L3); the *compute* — deciding
which bucket every record key belongs to, and the permutation that orders
a bucket — lives here, calling the L1 Pallas kernels so that everything
lowers into one HLO module per entry point.

Entry points (each AOT-lowered by aot.py to its own artifact):

* ``plan_partition(keys, bounds)``  -> (bucket_ids, histogram)
* ``plan_sort(keys)``               -> (sorted_keys, permutation)
* ``plan_sort_blocked(keys)``       -> per-tile independent sorts

All arrays are int32; keys must be non-negative (the bitonic kernel packs
(key, index) into an int64 composite).  Shapes are static per artifact —
the rust runtime pads the tail batch with i32::MAX sentinel keys, which
sort to the end and are dropped.
"""

import functools

import jax

from .kernels import bitonic
from .kernels.partition import partition as _partition


@jax.jit
def plan_partition(keys, bounds):
    """Bucket-classify ``keys`` against ``bounds``; returns (ids, histogram)."""
    return _partition(keys, bounds)


@jax.jit
def plan_sort(keys):
    """Sort one power-of-two tile of keys; returns (sorted, permutation)."""
    return bitonic.bitonic_sort(keys)


@functools.partial(jax.jit, static_argnames=("block",))
def plan_sort_blocked(keys, *, block):
    """Independently sort each ``block``-sized tile in one call."""
    return bitonic.bitonic_sort_blocked(keys, block=block)
