"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest/hypothesis suites compare the
Pallas kernels against.  They intentionally use only high-level jnp ops
(searchsorted / argsort) so that a bug in the hand-written kernels cannot
be mirrored here.
"""

import jax.numpy as jnp


def ref_partition(keys, bounds):
    """Classify each key into a bucket delimited by ``bounds``.

    ``bounds`` are the (num_buckets - 1) ascending bucket boundaries; key k
    lands in bucket ``sum(k >= bounds)`` (i.e. ``searchsorted(side='right')``).

    Returns ``(bucket_ids, histogram)`` with ``histogram.shape == (B,)``
    where ``B = len(bounds) + 1``.
    """
    keys = jnp.asarray(keys)
    bounds = jnp.asarray(bounds)
    bucket = jnp.searchsorted(bounds, keys, side="right").astype(jnp.int32)
    num_buckets = bounds.shape[0] + 1
    hist = jnp.zeros((num_buckets,), jnp.int32).at[bucket].add(1)
    return bucket, hist


def ref_sort(keys):
    """Stable sort of ``keys``; returns ``(sorted_keys, permutation)``.

    ``permutation[i]`` is the original index of the i-th smallest key, with
    ties broken by original index (stable), exactly matching the composite
    (key << 32 | index) ordering the bitonic kernel uses.
    """
    keys = jnp.asarray(keys)
    perm = jnp.argsort(keys, stable=True).astype(jnp.int32)
    return keys[perm], perm
