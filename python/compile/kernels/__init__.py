# L1: Pallas kernels for the WTF sort application's compute hot-spots.
from .bitonic import bitonic_sort, bitonic_sort_blocked  # noqa: F401
from .partition import partition  # noqa: F401
from .ref import ref_partition, ref_sort  # noqa: F401
