"""L1 Pallas kernel: bucket-classify a stream of record keys + histogram.

This is the compute hot-spot of the map/bucketing phase of the WTF sort
application (paper §4.1).  Given a block of int32 record keys and the
(B-1,) ascending bucket boundaries, emit the bucket id of every key and
the per-bucket histogram.  The WTF sort uses the bucket ids to *yank*
record slices into per-bucket files without rewriting the record bytes.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the key stream is tiled
through VMEM in ``block_size`` chunks by the BlockSpec; the boundary table
is tiny and resident for every grid step.  The classify is a dense
compare-reduce (keys[:,None] >= bounds[None,:]) which maps onto the VPU;
there is no data-dependent control flow.  The histogram output revisits
the same (B,) block every grid step and accumulates across steps.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the rust
runtime replays byte-for-byte.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _partition_kernel(bounds_ref, keys_ref, bucket_ref, hist_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    keys = keys_ref[...]
    bounds = bounds_ref[...]
    # bucket(k) = #bounds <= k  ==  searchsorted(bounds, k, side='right')
    bucket = jnp.sum(
        (keys[:, None] >= bounds[None, :]).astype(jnp.int32), axis=1
    ).astype(jnp.int32)
    bucket_ref[...] = bucket

    num_buckets = hist_ref.shape[0]
    onehot = (bucket[:, None] == jnp.arange(num_buckets)[None, :]).astype(jnp.int32)
    hist_ref[...] += jnp.sum(onehot, axis=0)


@functools.partial(jax.jit, static_argnames=("block_size",))
def partition(keys, bounds, *, block_size=2048):
    """Pallas bucket partition. ``keys``: (N,) int32, N % block_size == 0.

    ``bounds``: (B-1,) ascending int32.  Returns (bucket_ids (N,) int32,
    histogram (B,) int32).
    """
    n = keys.shape[0]
    if n % block_size != 0:
        raise ValueError(f"N={n} not a multiple of block_size={block_size}")
    num_buckets = bounds.shape[0] + 1
    if bounds.shape[0] == 0:
        # Degenerate single-bucket case: a zero-length BlockSpec dimension is
        # not representable, and the answer is trivially constant.
        return (
            jnp.zeros((n,), jnp.int32),
            jnp.full((1,), n, jnp.int32),
        )
    grid = (n // block_size,)
    return pl.pallas_call(
        _partition_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bounds.shape[0],), lambda i: (0,)),  # resident
            pl.BlockSpec((block_size,), lambda i: (i,)),  # streamed
        ],
        out_specs=[
            pl.BlockSpec((block_size,), lambda i: (i,)),
            pl.BlockSpec((num_buckets,), lambda i: (0,)),  # revisited
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((num_buckets,), jnp.int32),
        ],
        interpret=True,
    )(bounds, keys)
