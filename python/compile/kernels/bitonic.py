"""L1 Pallas kernel: bitonic sort of record keys, returning the permutation.

This is the compute hot-spot of the per-bucket sort phase of the WTF sort
application (paper §4.1).  The kernel returns sorted keys *and the
permutation indices*: the permutation is exactly what the file-slicing
sort needs, because it rearranges *slice pointers* (metadata) instead of
record bytes — the paper's core trick, expressed numerically.

Stability / determinism: each (key, index) pair is packed into one int64
composite ``(key << 32) | index`` so the network sorts lexicographically
by (key, original index); the result is bit-identical to a stable argsort.
Keys must be non-negative int32.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the whole tile is
VMEM-resident and the network is a fixed O(n log^2 n) sequence of
compare-exchange stages with *no data-dependent control flow* — each
stage is a gather + select over the full vector, i.e. pure VPU work; on
GPU the classic formulation uses warp shuffles, here the BlockSpec keeps
the tile resident instead.  VMEM footprint: n * 8 B (one int64 vector).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange(comp, n, k, j):
    pos = jnp.arange(n, dtype=jnp.int32)
    partner = pos ^ j
    other = comp[partner]
    ascending = (pos & k) == 0
    lower = pos < partner
    # Lower element of an ascending pair keeps the min; mirror for the rest.
    take_min = lower == ascending
    return jnp.where(take_min, jnp.minimum(comp, other), jnp.maximum(comp, other))


def _bitonic_kernel(keys_ref, sorted_ref, perm_ref):
    n = keys_ref.shape[0]
    keys = keys_ref[...]
    idx = jnp.arange(n, dtype=jnp.int32)
    comp = (keys.astype(jnp.int64) << 32) | idx.astype(jnp.int64)
    k = 2
    while k <= n:  # static python loops: the network unrolls at trace time
        j = k // 2
        while j >= 1:
            comp = _compare_exchange(comp, n, k, j)
            j //= 2
        k *= 2
    sorted_ref[...] = (comp >> 32).astype(jnp.int32)
    perm_ref[...] = (comp & 0xFFFFFFFF).astype(jnp.int32)


@jax.jit
def bitonic_sort(keys):
    """Sort (N,) non-negative int32 ``keys``; N must be a power of two.

    Returns (sorted_keys (N,) int32, permutation (N,) int32) where
    ``sorted_keys == keys[permutation]`` and the permutation is stable.
    """
    n = keys.shape[0]
    if n & (n - 1) != 0 or n == 0:
        raise ValueError(f"N={n} must be a power of two")
    return pl.pallas_call(
        _bitonic_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=True,
    )(keys)


@functools.partial(jax.jit, static_argnames=("block",))
def bitonic_sort_blocked(keys, *, block):
    """Grid variant: independently sort each ``block``-sized tile of keys.

    Used when one PJRT call sorts many buckets at once (N % block == 0).
    """
    n = keys.shape[0]
    if n % block != 0 or block & (block - 1) != 0:
        raise ValueError(f"N={n} must be a multiple of power-of-two block={block}")

    return pl.pallas_call(
        _bitonic_kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=True,
    )(keys)
