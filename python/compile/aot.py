"""AOT-lower the L2 entry points to HLO *text* artifacts for the rust runtime.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's bundled xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``).  The text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md.

Each (entry point, shape) pair becomes one self-contained artifact —
"one compiled executable per model variant".  A ``manifest.json`` records
every artifact's entry point, parameter shapes and dtypes so the rust
runtime can validate its inputs before execution.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (idempotent; the
Makefile only re-runs it when a python source changes).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (name, N keys, B buckets) partition variants.  N must be a multiple of the
# kernel block (2048).  B-1 boundary entries.
PARTITION_VARIANTS = [
    ("partition_n16384_b16", 16384, 16),
    ("partition_n65536_b64", 65536, 64),
]

# (name, N) whole-tile sort variants.  N must be a power of two.
SORT_VARIANTS = [
    ("sort_n1024", 1024),
    ("sort_n4096", 4096),
]

# (name, N total, block) blocked sort variants: N/block independent sorts.
SORT_BLOCKED_VARIANTS = [
    ("sort_n16384_block1024", 16384, 1024),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build_artifacts():
    """Yield (name, hlo_text, manifest_entry) for every variant."""
    for name, n, b in PARTITION_VARIANTS:
        lowered = jax.jit(model.plan_partition).lower(_spec((n,)), _spec((b - 1,)))
        yield name, to_hlo_text(lowered), {
            "entry": "plan_partition",
            "params": [
                {"name": "keys", "shape": [n], "dtype": "i32"},
                {"name": "bounds", "shape": [b - 1], "dtype": "i32"},
            ],
            "outputs": [
                {"name": "bucket_ids", "shape": [n], "dtype": "i32"},
                {"name": "histogram", "shape": [b], "dtype": "i32"},
            ],
            "n": n,
            "buckets": b,
        }
    for name, n in SORT_VARIANTS:
        lowered = jax.jit(model.plan_sort).lower(_spec((n,)))
        yield name, to_hlo_text(lowered), {
            "entry": "plan_sort",
            "params": [{"name": "keys", "shape": [n], "dtype": "i32"}],
            "outputs": [
                {"name": "sorted_keys", "shape": [n], "dtype": "i32"},
                {"name": "permutation", "shape": [n], "dtype": "i32"},
            ],
            "n": n,
        }
    for name, n, block in SORT_BLOCKED_VARIANTS:
        fn = lambda keys: model.plan_sort_blocked(keys, block=block)  # noqa: E731
        lowered = jax.jit(fn).lower(_spec((n,)))
        yield name, to_hlo_text(lowered), {
            "entry": "plan_sort_blocked",
            "params": [{"name": "keys", "shape": [n], "dtype": "i32"}],
            "outputs": [
                {"name": "sorted_keys", "shape": [n], "dtype": "i32"},
                {"name": "permutation", "shape": [n], "dtype": "i32"},
            ],
            "n": n,
            "block": block,
        }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--out", default=None, help="also write a stamp file")
    args = parser.parse_args()

    # The bitonic kernel packs (key, index) into int64 composites.
    jax.config.update("jax_enable_x64", True)

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {}
    for name, hlo, entry in build_artifacts():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        entry["file"] = f"{name}.hlo.txt"
        manifest[name] = entry
        print(f"wrote {path} ({len(hlo)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write("\n".join(sorted(manifest)) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
