"""L2 model shape checks + AOT artifact smoke tests."""

import json
import os

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

from compile import aot, model  # noqa: E402
from compile.kernels import ref_partition, ref_sort  # noqa: E402


def test_plan_partition_shapes():
    keys = np.zeros(4096, np.int32)
    bounds = np.arange(15, dtype=np.int32)
    ids, hist = model.plan_partition(keys, bounds)
    assert ids.shape == (4096,) and ids.dtype == np.int32
    assert hist.shape == (16,) and hist.dtype == np.int32
    assert int(np.asarray(hist).sum()) == 4096


def test_plan_sort_shapes():
    keys = np.arange(1024, dtype=np.int32)[::-1].copy()
    s, p = model.plan_sort(keys)
    assert s.shape == (1024,) and p.shape == (1024,)
    np.testing.assert_array_equal(np.asarray(s), np.arange(1024))


def test_plan_sort_blocked_matches_ref_per_tile():
    rng = np.random.default_rng(7)
    keys = rng.integers(0, 2**31 - 1, size=2048, dtype=np.int32)
    s, p = model.plan_sort_blocked(keys, block=1024)
    for t in range(2):
        tile = keys[t * 1024 : (t + 1) * 1024]
        ref_s, ref_p = ref_sort(tile)
        np.testing.assert_array_equal(np.asarray(s)[t * 1024 : (t + 1) * 1024], ref_s)
        np.testing.assert_array_equal(np.asarray(p)[t * 1024 : (t + 1) * 1024], ref_p)


def test_partition_histogram_consistency():
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 2**31 - 1, size=16384, dtype=np.int32)
    bounds = np.sort(rng.choice(2**31 - 1, size=15, replace=False)).astype(np.int32)
    ids, hist = model.plan_partition(keys, bounds)
    ref_ids, ref_hist = ref_partition(keys, bounds)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ref_ids))
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(ref_hist))


def test_aot_emits_parseable_hlo(tmp_path):
    # Lower ONE small variant end-to-end and sanity-check the HLO text.
    lowered = jax.jit(model.plan_sort).lower(
        jax.ShapeDtypeStruct((1024,), np.int32)
    )
    hlo = aot.to_hlo_text(lowered)
    assert "ENTRY" in hlo and "s32[1024]" in hlo
    out = tmp_path / "sort.hlo.txt"
    out.write_text(hlo)
    assert out.stat().st_size > 0


def test_aot_manifest_round_trip(tmp_path, monkeypatch):
    # Exercise main() on a trimmed variant list to keep the test fast.
    monkeypatch.setattr(aot, "PARTITION_VARIANTS", [("partition_n4096_b4", 4096, 4)])
    monkeypatch.setattr(aot, "SORT_VARIANTS", [("sort_n256", 256)])
    monkeypatch.setattr(aot, "SORT_BLOCKED_VARIANTS", [])
    monkeypatch.setattr(
        "sys.argv", ["aot", "--out-dir", str(tmp_path)]
    )
    aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert set(manifest) == {"partition_n4096_b4", "sort_n256"}
    for entry in manifest.values():
        assert os.path.exists(tmp_path / entry["file"])
        assert entry["params"][0]["dtype"] == "i32"
