"""Kernel-vs-reference correctness: the CORE signal for the L1 layer.

Hypothesis sweeps shapes and key distributions; every Pallas kernel output
is compared elementwise against the pure-jnp oracle in kernels/ref.py.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)  # bitonic packs int64 composites

from compile.kernels import (  # noqa: E402
    bitonic_sort,
    bitonic_sort_blocked,
    partition,
    ref_partition,
    ref_sort,
)

KEY_MAX = 2**31 - 1


def np_i32(xs):
    return np.asarray(xs, dtype=np.int32)


# ---------------------------------------------------------------- partition


def check_partition(keys, bounds, block_size):
    got_ids, got_hist = partition(np_i32(keys), np_i32(bounds), block_size=block_size)
    ref_ids, ref_hist = ref_partition(np_i32(keys), np_i32(bounds))
    np.testing.assert_array_equal(np.asarray(got_ids), np.asarray(ref_ids))
    np.testing.assert_array_equal(np.asarray(got_hist), np.asarray(ref_hist))


def test_partition_basic():
    keys = [5, 0, 99, 42, 10, 10, 9, 100]
    bounds = [10, 50]
    check_partition(keys, bounds, block_size=4)


def test_partition_single_bucket():
    # No boundaries: everything lands in bucket 0.
    check_partition(list(range(8)), [], block_size=8)


def test_partition_all_below_all_above():
    check_partition([0] * 8, [1], block_size=4)
    check_partition([KEY_MAX] * 8, [1], block_size=4)


def test_partition_boundary_is_inclusive_right():
    # key == bound goes to the upper bucket (searchsorted side='right').
    ids, hist = partition(np_i32([9, 10, 11]* 4), np_i32([10]), block_size=4)
    np.testing.assert_array_equal(np.asarray(ids)[:3], [0, 1, 1])


def test_partition_rejects_ragged():
    with pytest.raises(ValueError):
        partition(np_i32(list(range(10))), np_i32([5]), block_size=4)


@settings(max_examples=25, deadline=None)
@given(
    data=st.data(),
    log_blocks=st.integers(min_value=0, max_value=3),
    block_size=st.sampled_from([4, 16, 64]),
    n_bounds=st.integers(min_value=0, max_value=9),
)
def test_partition_matches_ref(data, log_blocks, block_size, n_bounds):
    n = block_size * (2**log_blocks)
    keys = data.draw(
        st.lists(st.integers(0, KEY_MAX), min_size=n, max_size=n), label="keys"
    )
    bounds = sorted(
        data.draw(
            st.lists(
                st.integers(0, KEY_MAX),
                min_size=n_bounds,
                max_size=n_bounds,
                unique=True,
            ),
            label="bounds",
        )
    )
    check_partition(keys, bounds, block_size=block_size)


# ------------------------------------------------------------------ bitonic


def check_sort(keys):
    got_sorted, got_perm = bitonic_sort(np_i32(keys))
    ref_sorted, ref_perm = ref_sort(np_i32(keys))
    np.testing.assert_array_equal(np.asarray(got_sorted), np.asarray(ref_sorted))
    np.testing.assert_array_equal(np.asarray(got_perm), np.asarray(ref_perm))


def test_sort_basic():
    check_sort([3, 1, 4, 1, 5, 9, 2, 6])


def test_sort_already_sorted():
    check_sort(list(range(16)))


def test_sort_reverse():
    check_sort(list(reversed(range(16))))


def test_sort_all_equal_is_stable():
    # Equal keys must keep original order (the int64 composite tie-break).
    _, perm = bitonic_sort(np_i32([7] * 16))
    np.testing.assert_array_equal(np.asarray(perm), np.arange(16))


def test_sort_size_one():
    check_sort([42])


def test_sort_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        bitonic_sort(np_i32([1, 2, 3]))


def test_sort_permutation_reconstructs():
    keys = np_i32([9, 3, 7, 3, 0, KEY_MAX, 12, 5])
    sorted_keys, perm = bitonic_sort(keys)
    np.testing.assert_array_equal(np.asarray(sorted_keys), keys[np.asarray(perm)])


@settings(max_examples=25, deadline=None)
@given(
    data=st.data(),
    log_n=st.integers(min_value=0, max_value=9),
)
def test_sort_matches_ref(data, log_n):
    n = 2**log_n
    keys = data.draw(
        st.lists(st.integers(0, KEY_MAX), min_size=n, max_size=n), label="keys"
    )
    check_sort(keys)


@settings(max_examples=10, deadline=None)
@given(
    data=st.data(),
    dupes=st.integers(min_value=2, max_value=16),
)
def test_sort_heavy_duplicates_stable(data, dupes):
    n = 64
    pool = data.draw(
        st.lists(st.integers(0, 100), min_size=dupes, max_size=dupes), label="pool"
    )
    keys = [pool[i % dupes] for i in range(n)]
    check_sort(keys)


# ---------------------------------------------------------------- blocked


def test_sort_blocked_independent_tiles():
    rng = np.random.default_rng(0)
    keys = rng.integers(0, KEY_MAX, size=4 * 256, dtype=np.int32)
    got_sorted, got_perm = bitonic_sort_blocked(keys, block=256)
    got_sorted, got_perm = np.asarray(got_sorted), np.asarray(got_perm)
    for t in range(4):
        tile = keys[t * 256 : (t + 1) * 256]
        ref_sorted, ref_perm = ref_sort(tile)
        np.testing.assert_array_equal(got_sorted[t * 256 : (t + 1) * 256], ref_sorted)
        # permutation indices are tile-local
        np.testing.assert_array_equal(got_perm[t * 256 : (t + 1) * 256], ref_perm)


def test_sort_blocked_rejects_bad_block():
    with pytest.raises(ValueError):
        bitonic_sort_blocked(np_i32(list(range(12))), block=6)
